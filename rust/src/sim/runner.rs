//! NDMP overlay simulator: drives a fleet of `NodeState` protocol engines
//! through the deterministic event queue over a pluggable `Transport`.
//! With the default in-memory backend (`SimTransport`) this is the
//! paper's "medium/large-scale simulation" substrate (§IV-A1, types 2–3)
//! for topology construction, maintenance, and churn experiments
//! (Figs. 8a–c); with `net::SchedTransport` the *same* event loop drives
//! the protocols over real localhost TCP sockets (§IV-A1, type 1).
//!
//! # Sharded execution
//!
//! `set_shards(k)` partitions the `[0,1)` space-0 virtual-coordinate
//! circle into `k` contiguous arcs. Each shard owns the node state
//! (arena-packed, see `sim::arena`) and the event sub-queue of its arc;
//! per instant, every shard's due `Deliver`/`Tick` events are processed
//! in parallel (rayon) and their emissions are merged back in producer
//! sequence order, while membership events (`Join`/`Fail`/`Leave`/
//! `Snapshot`) run serially on a control queue at their exact global
//! sequence positions. The result is *bitwise-identical* to the `k = 1`
//! serial loop — see `docs/perf.md` for the full determinism argument.

use super::arena::NodeArena;
use super::event::{Event, EventKind, EventQueue};
use super::network::SimTransport;
use super::transport::Transport;
use crate::config::{NetConfig, OverlayConfig};
use crate::ndmp::messages::{Msg, Outgoing, Time, MS};
use crate::ndmp::node::{Mutation, NodeCounters, NodeState};
use crate::ndmp::routing::coord_of;
use crate::topology::{correctness, IdealRings, NeighborSnapshot, NodeId};
use rayon::prelude::*;
use std::collections::{BTreeSet, VecDeque};

/// Below this many due events in a parallel segment the rayon fan-out
/// costs more than it saves; process serially (same code, same result).
const PAR_SEGMENT_MIN: usize = 64;

/// A recorded correctness sample (for the Fig. 8a/8b time series).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CorrectnessSample {
    pub at: Time,
    pub correctness: f64,
    pub live_nodes: usize,
}

/// Live-state footprint telemetry: everything here must stay bounded by
/// the *live set* (plus the peak live set for recycled slots), never by
/// churn history. The memory regression test pins these under a long
/// PoissonChurn run.
#[derive(Debug, Clone, Copy)]
pub struct FootprintStats {
    /// Arena slots allocated across all shards (live + recyclable).
    pub arena_slots: usize,
    /// Bytes of scheduler pending/cancelled bookkeeping (all queues).
    pub queue_bookkeeping_bytes: usize,
    /// Departed nodes folded into the scalar counter tally.
    pub retired_nodes: u64,
}

/// One arc of the coordinate circle: its nodes and its event sub-queue.
#[derive(Debug, Default)]
struct Shard {
    queue: EventQueue,
    nodes: NodeArena,
}

/// What one shard-local event produced, replayed serially at the merge
/// barrier in producer-seq order so global effects (counters, transport
/// delay streams, new event seqs) happen in exactly the serial order.
struct EventOut {
    seq: u64,
    delivered: Option<(NodeId, NodeId)>,
    view_change: Option<NodeId>,
    /// The event moved the target's `nbr_stamp` (its have-set changed):
    /// the merge barrier re-reads that node's neighbor set into the
    /// incremental correctness tracker. Carried as a delta — shard
    /// workers never touch the shared tracker.
    nbr_change: Option<NodeId>,
    /// `Tick` re-arm; seq-assigned *before* the sends, matching the
    /// serial loop's tick-first push order.
    rearm: Option<NodeId>,
    sends: Vec<(NodeId, Outgoing)>,
}

pub struct Simulator {
    pub cfg: OverlayConfig,
    /// Coordinate-arc shards; at the default `k = 1`, `shards[0]` is the
    /// whole simulator and the event loop is the classic serial one.
    shards: Vec<Shard>,
    /// Membership/snapshot events when sharded (`k > 1`): these mutate
    /// global state, so they run serially between parallel segments.
    ctl: EventQueue,
    /// Global sequence counter when sharded: every event gets its seq
    /// from here (in emission order), so ties at equal timestamps break
    /// exactly as in the single-queue run.
    next_seq: u64,
    pub now: Time,
    /// Message-passage backend: in-memory (`SimTransport`) or real TCP
    /// sockets (`net::SchedTransport`). Timers always stay on the queue.
    transport: Box<dyn Transport>,
    /// Tick granularity for node timers.
    tick_period: Time,
    /// Departed nodes folded into one scalar tally (message totals
    /// survive failures without O(history) per-node entries).
    retired_nodes: u64,
    retired_tally: NodeCounters,
    /// Incrementally-maintained Definition-1 ideal topology with running
    /// required/present tallies: membership events splice the persistent
    /// rings in O(L·log n) and `correctness()` reads the ratio in O(1)
    /// instead of re-sorting every ring per sample. Kept equal to the
    /// batch metric by construction (pinned by `tests/incremental_ideals`
    /// and `correctness_batch`).
    ideal: IdealRings,
    pub samples: Vec<CorrectnessSample>,
    /// Messages delivered (for telemetry / debugging).
    pub delivered: u64,
    /// Nodes whose Definition-1 ring views changed since the last
    /// `take_view_changes` drain (repair, join placement, failure
    /// detection, membership churn). Consumers — e.g. the trainer's
    /// per-client neighbor cache — invalidate exactly these entries
    /// instead of re-reading every node's views per wake.
    view_changes: BTreeSet<NodeId>,
    /// Cumulative count of view-change notifications (telemetry).
    pub view_change_count: u64,
    /// When enabled (`record_deliveries`), every delivered message is
    /// traced as `(virtual arrival time, from, to)` — the conformance
    /// suite's "identical arrival timestamps" comparison view. Off by
    /// default (the trace grows with every message).
    record_deliveries: bool,
    pub delivery_log: Vec<(Time, NodeId, NodeId)>,
    /// Fault injection installed on every node this simulator creates
    /// (`Mutation::None` outside the model checker's replay harness).
    mutation: Mutation,
}

impl Simulator {
    /// A simulator on the default in-memory transport (deterministic
    /// latency model from `net`).
    pub fn new(overlay: OverlayConfig, net: NetConfig) -> Self {
        let transport = Box::new(SimTransport::new(&net));
        Self::with_transport(overlay, transport)
    }

    /// A simulator on an explicit transport backend. The event loop,
    /// protocol engines, and churn scheduling are identical on every
    /// backend; only message passage differs.
    pub fn with_transport(overlay: OverlayConfig, transport: Box<dyn Transport>) -> Self {
        let tick_period = (overlay.heartbeat_ms * 1_000) / 2;
        let ideal = IdealRings::new(overlay.spaces);
        Self {
            cfg: overlay,
            shards: vec![Shard::default()],
            ctl: EventQueue::new(),
            next_seq: 0,
            now: 0,
            transport,
            tick_period: tick_period.max(1),
            retired_nodes: 0,
            retired_tally: NodeCounters::default(),
            ideal,
            samples: Vec::new(),
            delivered: 0,
            view_changes: BTreeSet::new(),
            view_change_count: 0,
            record_deliveries: false,
            delivery_log: Vec::new(),
            mutation: Mutation::None,
        }
    }

    /// Install a fault-injection [`Mutation`] on every node this
    /// simulator creates, so the model checker's counterexample schedules
    /// replay concretely against the *same* mutated protocol the abstract
    /// explorer swept. Must be called before any bootstrap or join so the
    /// whole fleet runs one protocol variant.
    pub fn set_mutation(&mut self, m: Mutation) {
        assert!(
            self.live_count() == 0,
            "set_mutation must be called before any bootstrap"
        );
        self.mutation = m;
    }

    /// Partition the simulator into `k` coordinate-arc shards. Must be
    /// called before any bootstrap or scheduling (the arc assignment of
    /// every queued event is fixed at enqueue time), and `k > 1`
    /// requires a queue-scheduled (idle) transport backend.
    pub fn set_shards(&mut self, k: usize) {
        assert!(k >= 1, "need at least one shard");
        assert!(
            self.now == 0
                && self.live_count() == 0
                && self.ctl.is_empty()
                && self.shards.iter().all(|s| s.queue.is_empty()),
            "set_shards must be called before any bootstrap or scheduling"
        );
        assert!(
            k == 1 || self.transport.idle(),
            "sharded execution requires a queue-scheduled transport (got {})",
            self.transport.name()
        );
        self.shards = std::iter::repeat_with(Shard::default).take(k).collect();
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Which shard owns `id`: `id`'s space-0 virtual coordinate mapped
    /// onto `k` equal arcs of `[0,1)`. A pure function of the id, so
    /// every run (and every `k`) agrees on ownership without any lookup
    /// state.
    #[inline]
    fn shard_of(&self, id: NodeId) -> usize {
        let k = self.shards.len();
        if k == 1 {
            return 0;
        }
        ((coord_of(id, 0) * k as f64) as usize).min(k - 1)
    }

    /// Toggle the per-message arrival trace (see `delivery_log`).
    pub fn record_deliveries(&mut self, on: bool) {
        self.record_deliveries = on;
    }

    /// Name of the message backend (`"sim"` or `"tcp"`).
    pub fn backend(&self) -> &'static str {
        self.transport.name()
    }

    /// Frames the transport's link-model loss lottery dropped so far
    /// (telemetry; the conformance suite pins sim ≡ tcp on this count).
    pub fn lost_frames(&self) -> u64 {
        self.transport.lost_frames()
    }

    /// Transport-level send failures (connect/write errors against live
    /// addresses). `0` on the in-memory backend, and asserted `0` for
    /// clean socket runs by the conformance suite.
    pub fn dropped_sends(&self) -> u64 {
        self.transport.dropped_sends()
    }

    /// Drain the set of nodes whose ring views changed since the last
    /// call (see `view_changes`).
    pub fn take_view_changes(&mut self) -> Vec<NodeId> {
        let drained: Vec<NodeId> = self.view_changes.iter().copied().collect();
        self.view_changes.clear();
        drained
    }

    fn note_view_change(&mut self, id: NodeId) {
        self.view_changes.insert(id);
        self.view_change_count += 1;
    }

    // ------------------------------------------------------------------
    // Node access (the arena replaces the old public BTreeMap)
    // ------------------------------------------------------------------

    pub fn node(&self, id: NodeId) -> Option<&NodeState> {
        self.shards[self.shard_of(id)].nodes.get(id)
    }

    pub fn node_mut(&mut self, id: NodeId) -> Option<&mut NodeState> {
        let s = self.shard_of(id);
        self.shards[s].nodes.get_mut(id)
    }

    pub fn contains_node(&self, id: NodeId) -> bool {
        self.shards[self.shard_of(id)].nodes.contains(id)
    }

    /// Number of live nodes.
    pub fn live_count(&self) -> usize {
        self.shards.iter().map(|s| s.nodes.len()).sum()
    }

    /// Live node ids in ascending order.
    pub fn node_ids(&self) -> Vec<NodeId> {
        let mut ids: Vec<NodeId> = self
            .shards
            .iter()
            .flat_map(|s| s.nodes.ids_sorted())
            .collect();
        ids.sort_unstable();
        ids
    }

    fn insert_node(&mut self, st: NodeState) {
        let s = self.shard_of(st.id);
        self.shards[s].nodes.insert(st);
    }

    fn remove_node(&mut self, id: NodeId) -> Option<NodeState> {
        let s = self.shard_of(id);
        self.shards[s].nodes.remove(id)
    }

    /// Fold a departed node's counters into the scalar tally.
    fn retire(&mut self, counters: NodeCounters) {
        self.retired_nodes += 1;
        self.retired_tally.absorb(&counters);
    }

    /// Re-read the have-sets of `ids` into the incremental tracker.
    /// Called for the nodes a membership splice touched and for nodes
    /// whose `nbr_stamp` moved during event processing. Ids that are no
    /// longer live are skipped — the tracker has already dropped their
    /// edges.
    fn refresh_ideal(&mut self, ids: &[NodeId]) {
        for &id in ids {
            let s = self.shard_of(id);
            if let Some(st) = self.shards[s].nodes.get(id) {
                let have = st.neighbor_ids();
                self.ideal.refresh(id, &have);
            }
        }
    }

    /// Live-state footprint telemetry (see `FootprintStats`).
    pub fn footprint(&self) -> FootprintStats {
        FootprintStats {
            arena_slots: self.shards.iter().map(|s| s.nodes.slot_capacity()).sum(),
            queue_bookkeeping_bytes: self
                .shards
                .iter()
                .map(|s| s.queue.bookkeeping_bytes())
                .sum::<usize>()
                + self.ctl.bookkeeping_bytes(),
            retired_nodes: self.retired_nodes,
        }
    }

    /// Create a correct network of `ids` instantly (centralized shortcut
    /// used to set up the *initial* condition of churn experiments; the
    /// decentralized path is `schedule_join`). One ring sort per space —
    /// not per node — so 10k-node scenarios bootstrap in milliseconds.
    pub fn bootstrap_correct(&mut self, ids: &[NodeId]) {
        use crate::topology::fedlay::Membership;
        use std::collections::BTreeMap;
        let mut m = Membership::new(self.cfg.spaces);
        for &id in ids {
            m.add(id);
        }
        // id -> (prev, next) per space, from a single sorted ring each
        let mut adjacency: Vec<BTreeMap<NodeId, (NodeId, NodeId)>> = Vec::new();
        for s in 0..self.cfg.spaces {
            let ring = m.ring(s);
            let n = ring.len();
            let mut tab = BTreeMap::new();
            if n >= 2 {
                for pos in 0..n {
                    tab.insert(
                        ring[pos].id,
                        (ring[(pos + n - 1) % n].id, ring[(pos + 1) % n].id),
                    );
                }
            }
            adjacency.push(tab);
        }
        for &id in ids {
            let mut st = NodeState::new(id, self.cfg.clone(), self.now);
            st.mutation = self.mutation;
            st.bootstrap_first();
            for (s, tab) in adjacency.iter().enumerate() {
                if let Some(&(prev, next)) = tab.get(&id) {
                    st.views[s].prev = Some(prev);
                    st.views[s].next = Some(next);
                }
            }
            // seed the peer table from the views
            for s in 0..self.cfg.spaces {
                if let Some(p) = st.views[s].prev {
                    st.handle(p, Msg::Heartbeat, self.now);
                }
                if let Some(nx) = st.views[s].next {
                    st.handle(nx, Msg::Heartbeat, self.now);
                }
            }
            // zero the counters: bootstrap is not protocol traffic
            st.counters = NodeCounters::default();
            self.transport.open(id).expect("transport endpoint");
            self.insert_node(st);
            self.ideal.add(id);
            self.note_view_change(id);
            self.enqueue(self.now + 1, EventKind::Tick { node: id });
        }
        // seed the presence tallies once every have-set is final (the
        // per-add touched sets would re-read intermediate states)
        self.refresh_ideal(ids);
    }

    /// Start an empty network with a single node.
    pub fn bootstrap_single(&mut self, id: NodeId) {
        let mut st = NodeState::new(id, self.cfg.clone(), self.now);
        st.mutation = self.mutation;
        st.bootstrap_first();
        self.transport.open(id).expect("transport endpoint");
        self.insert_node(st);
        self.ideal.add(id);
        self.note_view_change(id);
        self.enqueue(self.now + 1, EventKind::Tick { node: id });
    }

    pub fn schedule_join(&mut self, at: Time, node: NodeId, bootstrap: NodeId) {
        self.enqueue(at, EventKind::Join { node, bootstrap });
    }

    pub fn schedule_fail(&mut self, at: Time, node: NodeId) {
        self.enqueue(at, EventKind::Fail { node });
    }

    pub fn schedule_leave(&mut self, at: Time, node: NodeId) {
        self.enqueue(at, EventKind::Leave { node });
    }

    pub fn schedule_snapshot(&mut self, at: Time) {
        self.enqueue(at, EventKind::Snapshot { tag: 0 });
    }

    /// Route an event to its owning queue. At `k = 1` this is a plain
    /// push (the queue's internal counter numbers events in emission
    /// order); when sharded, the global counter assigns the *same*
    /// numbers in the same order and the event lands on its arc's
    /// sub-queue (`Deliver`/`Tick`) or the serial control queue
    /// (membership, snapshots).
    fn enqueue(&mut self, at: Time, kind: EventKind) {
        if self.shards.len() == 1 {
            self.shards[0].queue.push(at, kind);
            return;
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        let shard = match &kind {
            EventKind::Deliver { to, .. } => Some(self.shard_of(*to)),
            EventKind::Tick { node } => Some(self.shard_of(*node)),
            _ => None,
        };
        match shard {
            Some(s) => {
                self.shards[s].queue.push_at_seq(at, seq, kind);
            }
            None => {
                self.ctl.push_at_seq(at, seq, kind);
            }
        }
    }

    fn dispatch(&mut self, from: NodeId, outs: Vec<Outgoing>) {
        for o in outs {
            if o.to == from {
                continue;
            }
            // Queue-scheduled backends answer with a delivery time; wire
            // backends carry the bytes themselves and we poll (`pump`).
            if let Some(at) = self.transport.send(self.now, from, o.to, &o.msg) {
                self.enqueue(
                    at,
                    EventKind::Deliver {
                        from,
                        to: o.to,
                        msg: o.msg,
                    },
                );
            }
        }
    }

    /// Collect frames the transport carried out-of-band (socket
    /// backends) and schedule each as a `Deliver` event at its stamped
    /// virtual arrival time — the same queue path the in-memory backend
    /// takes, so both backends process deliveries in the identical
    /// order. A no-op on the in-memory backend.
    ///
    /// `poll` returns arrivals in (due time, send order); pushing them
    /// in that order reproduces the in-memory backend's queue insertion
    /// order for equal-time ties. Stamps are always in the future of the
    /// sending instant (delays are >= 1 µs); the `max` only guards
    /// frames a slow loopback surfaced after their due instant, which
    /// are delivered as soon as possible instead of rewinding the clock.
    fn pump(&mut self) {
        if self.transport.idle() {
            return;
        }
        for a in self.transport.poll() {
            let at = a.at.max(self.now);
            self.enqueue(
                at,
                EventKind::Deliver {
                    from: a.from,
                    to: a.to,
                    msg: a.msg,
                },
            );
        }
    }

    /// Current neighbor-set snapshot of all live nodes.
    pub fn snapshot(&self) -> NeighborSnapshot {
        self.shards
            .iter()
            .flat_map(|s| s.nodes.iter_unordered())
            .map(|st| (st.id, st.neighbor_ids()))
            .collect()
    }

    /// Ring-adjacency snapshot (Definition-1 views only, excluding
    /// incidental routed-traffic peers). Two converged backends must
    /// agree on this exactly — the conformance-test comparison view.
    pub fn ring_snapshot(&self) -> NeighborSnapshot {
        self.shards
            .iter()
            .flat_map(|s| s.nodes.iter_unordered())
            .map(|st| (st.id, st.ring_neighbor_ids()))
            .collect()
    }

    /// The live overlay as an undirected graph (indices follow sorted id
    /// order; the second value maps graph index -> node id).
    pub fn live_graph(&self) -> (crate::graph::Graph, Vec<NodeId>) {
        correctness::graph_from_snapshot(&self.snapshot())
    }

    /// The §IV-A3 correctness ratio from the incremental tracker's
    /// running tallies — O(1), no fleet-wide snapshot, no ring sorts.
    /// Equal (bitwise: same integer tallies, same division) to
    /// `correctness_batch`, which stays around as the oracle.
    pub fn correctness(&self) -> f64 {
        self.ideal.correctness()
    }

    /// The batch-path correctness: materialize the fleet snapshot and
    /// rebuild the ideal rings from scratch (O(L·n log n)). The oracle
    /// the incremental path is pinned against; prefer `correctness()`.
    pub fn correctness_batch(&self) -> f64 {
        correctness(&self.snapshot(), self.cfg.spaces)
    }

    /// Detailed correctness report, reusing the incrementally-maintained
    /// ideal instead of re-deriving it from the snapshot's live ids.
    pub fn correctness_report(&self) -> correctness::CorrectnessReport {
        correctness::report_against_ideal(&self.snapshot(), &self.ideal.ideal_snapshot())
    }

    /// Read access to the incremental ideal tracker (generation stamp,
    /// tallies, per-node `want` sets) for tests and telemetry.
    pub fn ideal(&self) -> &IdealRings {
        &self.ideal
    }

    /// Total control messages sent per live+retired node.
    pub fn control_messages_per_node(&self) -> f64 {
        let live: u64 = self
            .shards
            .iter()
            .flat_map(|s| s.nodes.iter_unordered())
            .map(|n| n.counters.control_sent)
            .sum();
        let count = self.live_count() as u64 + self.retired_nodes;
        if count == 0 {
            0.0
        } else {
            (live + self.retired_tally.control_sent) as f64 / count as f64
        }
    }

    /// Pop the globally-earliest pending event (tools and tests drain
    /// schedules through this; the run loop batches internally).
    pub fn pop_event(&mut self) -> Option<Event> {
        let mut best: Option<(Time, u64, usize)> = None;
        for (i, s) in self.shards.iter_mut().enumerate() {
            if let Some(e) = s.queue.peek() {
                if best.is_none_or(|(at, seq, _)| (e.at, e.seq) < (at, seq)) {
                    best = Some((e.at, e.seq, i));
                }
            }
        }
        if let Some(e) = self.ctl.peek() {
            if best.is_none_or(|(at, seq, _)| (e.at, e.seq) < (at, seq)) {
                best = Some((e.at, e.seq, usize::MAX));
            }
        }
        let (_, _, idx) = best?;
        if idx == usize::MAX {
            self.ctl.pop()
        } else {
            self.shards[idx].queue.pop()
        }
    }

    /// Process one event exactly as the serial loop does. The sharded
    /// loop reuses this verbatim for control events, so membership
    /// handling (and its emission seq assignment) is shared, not
    /// reimplemented.
    fn handle_event(&mut self, kind: EventKind) {
        let now = self.now;
        match kind {
            EventKind::Deliver { from, to, msg } => {
                // Messages to dead nodes vanish (crash-fail model)
                // *before* counting: the wire backend never has a
                // frame for them (the send is dropped at the closed
                // endpoint), so counting them here would make
                // `delivered` and the delivery log diverge between
                // backends.
                let s = self.shard_of(to);
                let Some(node) = self.shards[s].nodes.get_mut(to) else {
                    return;
                };
                let stamp = node.view_stamp();
                let nstamp = node.nbr_stamp();
                let outs = node.handle(from, msg, now);
                let changed = node.view_stamp() != stamp;
                let have = (node.nbr_stamp() != nstamp).then(|| node.neighbor_ids());
                self.delivered += 1;
                if self.record_deliveries {
                    self.delivery_log.push((now, from, to));
                }
                if changed {
                    self.note_view_change(to);
                }
                if let Some(have) = have {
                    self.ideal.refresh(to, &have);
                }
                self.dispatch(to, outs);
            }
            EventKind::Tick { node } => {
                let s = self.shard_of(node);
                let Some(st) = self.shards[s].nodes.get_mut(node) else {
                    return;
                };
                let stamp = st.view_stamp();
                let nstamp = st.nbr_stamp();
                let outs = st.tick(now);
                let changed = st.view_stamp() != stamp;
                let have = (st.nbr_stamp() != nstamp).then(|| st.neighbor_ids());
                if changed {
                    self.note_view_change(node);
                }
                if let Some(have) = have {
                    self.ideal.refresh(node, &have);
                }
                // push the next tick *before* dispatching: the wire
                // backend's deliveries enter the queue after the
                // event (in `pump`), so a uniform tick-first order
                // keeps equal-time tie-breaking identical on both
                // backends
                self.enqueue(now + self.tick_period, EventKind::Tick { node });
                self.dispatch(node, outs);
            }
            EventKind::Join { node, bootstrap } => {
                if self.contains_node(node) || !self.contains_node(bootstrap) {
                    return;
                }
                if self.transport.open(node).is_err() {
                    return; // endpoint unavailable: the join is lost
                }
                let mut st = NodeState::new(node, self.cfg.clone(), now);
                st.mutation = self.mutation;
                let outs = st.start_join(bootstrap, now);
                self.insert_node(st);
                // splice the joiner into the persistent ideal rings and
                // re-read every endpoint the splice touched
                let touched = self.ideal.add(node);
                self.refresh_ideal(&touched);
                self.note_view_change(node);
                // tick before dispatch: see the Tick arm
                self.enqueue(now + self.tick_period, EventKind::Tick { node });
                self.dispatch(node, outs);
            }
            EventKind::Fail { node } => {
                if let Some(st) = self.remove_node(node) {
                    self.retire(st.counters);
                    let touched = self.ideal.remove(node);
                    self.refresh_ideal(&touched);
                    self.note_view_change(node);
                    self.transport.close(node);
                }
            }
            EventKind::Leave { node } => {
                if let Some(mut st) = self.remove_node(node) {
                    let outs = st.start_leave();
                    self.retire(st.counters);
                    let touched = self.ideal.remove(node);
                    self.refresh_ideal(&touched);
                    self.note_view_change(node);
                    // flush the leave notices, then tear the endpoint
                    // down — in-flight messages to it vanish, exactly
                    // like the in-memory dead-node rule.
                    self.dispatch(node, outs);
                    self.transport.close(node);
                }
            }
            EventKind::Snapshot { .. } => {
                // O(1) read of the running tallies — sampling cadence no
                // longer serializes the fleet or re-sorts the rings
                let c = self.correctness();
                self.samples.push(CorrectnessSample {
                    at: now,
                    correctness: c,
                    live_nodes: self.live_count(),
                });
            }
        }
    }

    /// Run until `deadline` (inclusive) or the queue drains. Timer and
    /// churn events pop from the deterministic queue; between events any
    /// wire-carried messages are pumped in. Sharded simulators take the
    /// parallel instant-batch loop instead (identical results).
    pub fn run_until(&mut self, deadline: Time) {
        if self.shards.len() > 1 {
            self.run_until_sharded(deadline);
            return;
        }
        self.pump();
        while let Some(t) = self.shards[0].queue.peek_time() {
            if t > deadline {
                break;
            }
            let ev = self.shards[0].queue.pop().unwrap();
            self.now = ev.at;
            self.handle_event(ev.kind);
            self.pump();
        }
        self.now = self.now.max(deadline);
        self.pump();
    }

    /// The sharded event loop: per instant, pop everything due, process
    /// shard-local events in parallel between serial control events, and
    /// merge emissions in producer-seq order. Why this is bitwise equal
    /// to the serial loop:
    ///
    /// * all emissions land strictly later than the current instant
    ///   (link delays and tick periods are >= 1 µs), so the due set of
    ///   an instant is fixed before any of it is processed;
    /// * `Deliver`/`Tick` handlers touch only the target node's state,
    ///   which lives in exactly one shard — events of different shards
    ///   at the same instant commute as long as no membership event
    ///   sits between them (in seq order), which is what the segment
    ///   split enforces;
    /// * all *global* effects — `delivered`, the delivery log, view
    ///   changes, transport delay sampling, and the seqs of emitted
    ///   events — are applied at the merge barrier in producer-seq
    ///   order, i.e. in exactly the serial processing order.
    fn run_until_sharded(&mut self, deadline: Time) {
        debug_assert!(self.transport.idle());
        loop {
            let mut t_min = self.ctl.peek_time();
            for s in &mut self.shards {
                t_min = match (t_min, s.queue.peek_time()) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, b) => a.or(b),
                };
            }
            let Some(t) = t_min else { break };
            if t > deadline {
                break;
            }
            self.now = t;
            self.step_instant_sharded(t);
        }
        self.now = self.now.max(deadline);
    }

    fn step_instant_sharded(&mut self, t: Time) {
        // every control event due at this instant, in seq order
        let mut ctl_due: Vec<Event> = Vec::new();
        while self.ctl.peek().is_some_and(|e| e.at == t) {
            ctl_due.push(self.ctl.pop().unwrap());
        }
        // every shard event due at this instant, per shard (seq-sorted:
        // a queue pops equal times in seq order)
        let mut due: Vec<VecDeque<Event>> = self
            .shards
            .iter_mut()
            .map(|s| {
                let mut v = VecDeque::new();
                while s.queue.peek().is_some_and(|e| e.at == t) {
                    v.push_back(s.queue.pop().unwrap());
                }
                v
            })
            .collect();
        // walk the instant in global seq order: shard events between
        // consecutive control seqs form one parallel segment; each
        // control event is a serial barrier at its exact position.
        let mut ctl_iter = ctl_due.into_iter();
        let mut next_ctl = ctl_iter.next();
        loop {
            let boundary = next_ctl.as_ref().map_or(u64::MAX, |e| e.seq);
            let segment: Vec<Vec<Event>> = due
                .iter_mut()
                .map(|q| {
                    let mut v = Vec::new();
                    while q.front().is_some_and(|e| e.seq < boundary) {
                        v.push(q.pop_front().unwrap());
                    }
                    v
                })
                .collect();
            self.run_segment(segment);
            match next_ctl.take() {
                Some(e) => {
                    self.handle_event(e.kind);
                    next_ctl = ctl_iter.next();
                }
                None => break,
            }
        }
        debug_assert!(due.iter().all(|q| q.is_empty()));
    }

    /// Process one parallel segment: shard-local events fan out across
    /// shards (rayon when large enough), then their outputs are merged
    /// and applied serially in producer-seq order.
    fn run_segment(&mut self, segment: Vec<Vec<Event>>) {
        let total: usize = segment.iter().map(|v| v.len()).sum();
        if total == 0 {
            return;
        }
        let now = self.now;
        let outs: Vec<Vec<EventOut>> = if total >= PAR_SEGMENT_MIN {
            self.shards
                .par_iter_mut()
                .zip(segment.into_par_iter())
                .map(|(shard, evs)| process_shard_events(shard, evs, now))
                .collect()
        } else {
            self.shards
                .iter_mut()
                .zip(segment)
                .map(|(shard, evs)| process_shard_events(shard, evs, now))
                .collect()
        };
        let mut merged: Vec<EventOut> = outs.into_iter().flatten().collect();
        merged.sort_unstable_by_key(|o| o.seq);
        let mut nbr_changed: BTreeSet<NodeId> = BTreeSet::new();
        for out in merged {
            if let Some((from, to)) = out.delivered {
                self.delivered += 1;
                if self.record_deliveries {
                    self.delivery_log.push((now, from, to));
                }
            }
            if let Some(id) = out.view_change {
                self.note_view_change(id);
            }
            if let Some(id) = out.nbr_change {
                nbr_changed.insert(id);
            }
            if let Some(node) = out.rearm {
                self.enqueue(now + self.tick_period, EventKind::Tick { node });
            }
            for (from, o) in out.sends {
                if let Some(at) = self.transport.send(now, from, o.to, &o.msg) {
                    self.enqueue(
                        at,
                        EventKind::Deliver {
                            from,
                            to: o.to,
                            msg: o.msg,
                        },
                    );
                }
            }
        }
        // refresh each changed node once from its *post-segment* state.
        // `refresh` is idempotent in the final have-set, so folding a
        // node's several within-segment refreshes (as the serial loop
        // performs them) into one is tally-identical: the next control
        // barrier — the only place tallies are read — sees the same
        // flags either way.
        let changed: Vec<NodeId> = nbr_changed.into_iter().collect();
        self.refresh_ideal(&changed);
    }

    /// Convenience: run until correctness reaches `threshold` or `deadline`
    /// passes; returns the time correctness first reached the threshold.
    pub fn run_until_correct(
        &mut self,
        threshold: f64,
        deadline: Time,
        check_every: Time,
    ) -> Option<Time> {
        loop {
            let next = (self.now + check_every).min(deadline);
            self.run_until(next);
            if self.correctness() >= threshold {
                return Some(self.now);
            }
            if self.now >= deadline {
                return None;
            }
        }
    }
}

/// The shard-local half of event processing: run each due event's
/// protocol handler against this shard's nodes, recording global effects
/// for the serial merge instead of applying them. Self-sends are dropped
/// here (as in `dispatch`); everything else that touches shared state
/// waits for the merge barrier.
fn process_shard_events(shard: &mut Shard, evs: Vec<Event>, now: Time) -> Vec<EventOut> {
    let mut outs = Vec::with_capacity(evs.len());
    for ev in evs {
        match ev.kind {
            EventKind::Deliver { from, to, msg } => {
                let Some(node) = shard.nodes.get_mut(to) else {
                    continue; // dead target: vanishes, uncounted
                };
                let stamp = node.view_stamp();
                let nstamp = node.nbr_stamp();
                let emitted = node.handle(from, msg, now);
                let view_change = (node.view_stamp() != stamp).then_some(to);
                let nbr_change = (node.nbr_stamp() != nstamp).then_some(to);
                outs.push(EventOut {
                    seq: ev.seq,
                    delivered: Some((from, to)),
                    view_change,
                    nbr_change,
                    rearm: None,
                    sends: emitted
                        .into_iter()
                        .filter(|o| o.to != to)
                        .map(|o| (to, o))
                        .collect(),
                });
            }
            EventKind::Tick { node } => {
                let Some(st) = shard.nodes.get_mut(node) else {
                    continue; // departed: timer chain ends
                };
                let stamp = st.view_stamp();
                let nstamp = st.nbr_stamp();
                let emitted = st.tick(now);
                let view_change = (st.view_stamp() != stamp).then_some(node);
                let nbr_change = (st.nbr_stamp() != nstamp).then_some(node);
                outs.push(EventOut {
                    seq: ev.seq,
                    delivered: None,
                    view_change,
                    nbr_change,
                    rearm: Some(node),
                    sends: emitted
                        .into_iter()
                        .filter(|o| o.to != node)
                        .map(|o| (node, o))
                        .collect(),
                });
            }
            other => unreachable!("control event {other:?} routed to a shard queue"),
        }
    }
    outs
}

/// Build a network of `n` nodes purely through the decentralized join
/// protocol, one join per `spacing` (sequential joins, §III-B1).
pub fn grow_network(
    overlay: OverlayConfig,
    net: NetConfig,
    n: usize,
    spacing: Time,
) -> Simulator {
    let mut sim = Simulator::new(overlay, net);
    sim.bootstrap_single(0);
    for i in 1..n as NodeId {
        // join via a deterministic pseudo-random existing node
        let bootstrap = i.wrapping_mul(0x9E37_79B9_7F4A_7C15) % i;
        sim.schedule_join(sim.now + i * spacing, i, bootstrap);
    }
    // run past the last scheduled join first — checking correctness any
    // earlier would "pass" on a partially-grown (but locally correct)
    // network — then settle until Definition-1 correctness over all n.
    sim.run_until(n as Time * spacing + 1);
    let deadline = n as Time * spacing + 60_000 * MS;
    sim.run_until_correct(1.0, deadline, 2_000 * MS);
    debug_assert_eq!(sim.live_count(), n, "grow_network lost joiners");
    sim
}

#[cfg(test)]
mod tests {
    use super::*;

    fn overlay(spaces: usize) -> OverlayConfig {
        OverlayConfig {
            spaces,
            heartbeat_ms: 500,
            failure_multiple: 3,
            repair_probe_ms: 2_000,
        }
    }

    fn net() -> NetConfig {
        NetConfig {
            latency_ms: 50.0,
            jitter: 0.2,
            seed: 5,
            ..NetConfig::default()
        }
    }

    #[test]
    fn bootstrap_correct_is_correct() {
        let mut sim = Simulator::new(overlay(3), net());
        let ids: Vec<NodeId> = (0..50).collect();
        sim.bootstrap_correct(&ids);
        assert!((sim.correctness() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sequential_joins_converge_to_correct() {
        let sim = grow_network(overlay(2), net(), 20, 2_000 * MS);
        assert!(
            sim.correctness() > 0.999,
            "correctness {}",
            sim.correctness()
        );
    }

    #[test]
    fn live_graph_matches_bootstrap_topology() {
        let mut sim = Simulator::new(overlay(3), net());
        sim.bootstrap_correct(&(0..30).collect::<Vec<_>>());
        let (g, ids) = sim.live_graph();
        assert_eq!(ids.len(), 30);
        assert!(g.max_degree() <= 6, "degree bound 2L violated");
        assert!(crate::graph::traversal::is_connected(&g));
    }

    #[test]
    fn single_failure_recovers() {
        let mut sim = Simulator::new(overlay(2), net());
        let ids: Vec<NodeId> = (0..30).collect();
        sim.bootstrap_correct(&ids);
        sim.schedule_fail(10 * MS, 7);
        // allow detection (3 * 500ms) + repair routing
        let t = sim.run_until_correct(1.0, 60_000 * MS, 500 * MS);
        assert!(t.is_some(), "failure not repaired; c={}", sim.correctness());
    }

    #[test]
    fn graceful_leave_repairs_instantly() {
        let mut sim = Simulator::new(overlay(2), net());
        let ids: Vec<NodeId> = (0..25).collect();
        sim.bootstrap_correct(&ids);
        sim.schedule_leave(10 * MS, 11);
        let t = sim.run_until_correct(1.0, 20_000 * MS, 100 * MS);
        assert!(t.is_some(), "leave not repaired; c={}", sim.correctness());
        assert!(!sim.contains_node(11));
    }

    #[test]
    fn concurrent_joins_converge() {
        let mut sim = Simulator::new(overlay(2), net());
        let ids: Vec<NodeId> = (0..20).collect();
        sim.bootstrap_correct(&ids);
        // 10 concurrent joins at the same instant through random nodes
        for j in 100..110u64 {
            sim.schedule_join(10 * MS, j, j % 20);
        }
        let t = sim.run_until_correct(1.0, 120_000 * MS, 1_000 * MS);
        assert!(
            t.is_some(),
            "concurrent joins did not converge; c={}",
            sim.correctness()
        );
        assert_eq!(sim.live_count(), 30);
    }

    #[test]
    fn concurrent_failures_recover() {
        let mut sim = Simulator::new(overlay(3), net());
        let ids: Vec<NodeId> = (0..40).collect();
        sim.bootstrap_correct(&ids);
        for f in [3u64, 9, 21, 33] {
            sim.schedule_fail(10 * MS, f);
        }
        let t = sim.run_until_correct(1.0, 180_000 * MS, 1_000 * MS);
        assert!(
            t.is_some(),
            "concurrent failures did not recover; c={}",
            sim.correctness()
        );
        assert_eq!(sim.live_count(), 36);
    }

    #[test]
    fn view_changes_track_churn_and_go_quiet() {
        use crate::sim::scenario::quiesce;
        let mut sim = Simulator::new(overlay(2), net());
        sim.bootstrap_correct(&(0..20).collect::<Vec<_>>());
        // bootstrap notifies every node once
        let boot: Vec<NodeId> = sim.take_view_changes();
        assert_eq!(boot.len(), 20);
        sim.schedule_fail(10 * MS, 3);
        // run past the failure instant, then settle to the exact ideal
        // rings (stronger than correctness 1.0: no residual adoptions
        // left to fire during the quiet window)
        sim.run_until(1_000 * MS);
        let t = quiesce(&mut sim, 120_000 * MS, 500 * MS);
        assert!(t.is_some(), "failure not repaired: {}", sim.correctness());
        let changed = sim.take_view_changes();
        // the failed node and (at least) its ring neighbors changed views
        assert!(changed.contains(&3));
        assert!(changed.len() >= 3, "repair should touch neighbors: {changed:?}");
        assert!(sim.view_change_count >= changed.len() as u64);
        // a converged, churn-free network stays quiet
        let quiet_from = sim.now;
        sim.run_until(quiet_from + 20_000 * MS);
        assert!(
            sim.take_view_changes().is_empty(),
            "steady-state heartbeats must not emit view changes"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut sim = Simulator::new(overlay(2), net());
            sim.bootstrap_correct(&(0..15).collect::<Vec<_>>());
            sim.schedule_fail(5 * MS, 3);
            sim.schedule_join(6 * MS, 99, 1);
            sim.run_until(30_000 * MS);
            (sim.correctness(), sim.delivered, sim.control_messages_per_node())
        };
        assert_eq!(run(), run());
    }

    /// The tentpole invariant in miniature: a sharded run is *bitwise*
    /// identical to the serial run — same delivered count, same arrival
    /// log, same counters, same rings, same samples.
    #[test]
    fn sharded_run_is_bitwise_identical_to_serial() {
        let run = |k: usize| {
            let mut sim = Simulator::new(overlay(2), net());
            sim.set_shards(k);
            sim.record_deliveries(true);
            sim.bootstrap_correct(&(0..24).collect::<Vec<_>>());
            sim.schedule_fail(5 * MS, 3);
            sim.schedule_join(6 * MS, 99, 1);
            sim.schedule_leave(9 * MS, 17);
            for t in [2_000 * MS, 10_000 * MS, 25_000 * MS] {
                sim.schedule_snapshot(t);
            }
            sim.run_until(30_000 * MS);
            (
                sim.delivered,
                sim.delivery_log.clone(),
                sim.control_messages_per_node(),
                sim.correctness(),
                sim.ring_snapshot(),
                sim.samples.clone(),
                sim.view_change_count,
            )
        };
        let serial = run(1);
        for k in [2, 4, 7] {
            assert_eq!(serial, run(k), "shard count {k} diverged");
        }
    }

    /// The full link model (bandwidth + loss + node caps) is as
    /// deterministic and sharding-invariant as the latency-only one:
    /// identical delivered/lost counts, arrival log, and rings at any K.
    #[test]
    fn lossy_run_is_deterministic_and_sharding_invariant() {
        let lossy = NetConfig {
            latency_ms: 50.0,
            jitter: 0.2,
            bandwidth_mbps: 5.0,
            loss: 0.05,
            node_up_mbps: 20.0,
            node_down_mbps: 20.0,
            seed: 5,
        };
        let run = |k: usize| {
            let mut sim = Simulator::new(overlay(2), lossy.clone());
            sim.set_shards(k);
            sim.record_deliveries(true);
            sim.bootstrap_correct(&(0..24).collect::<Vec<_>>());
            sim.schedule_fail(5 * MS, 3);
            sim.schedule_join(6 * MS, 99, 1);
            sim.run_until(30_000 * MS);
            (
                sim.delivered,
                sim.lost_frames(),
                sim.delivery_log.clone(),
                sim.correctness(),
                sim.ring_snapshot(),
            )
        };
        let serial = run(1);
        assert!(serial.1 > 0, "5% loss over 30s of heartbeats must drop frames");
        assert_eq!(serial, run(1), "lossy runs must replay identically");
        for k in [2, 4] {
            assert_eq!(serial, run(k), "shard count {k} diverged under loss");
        }
    }

    #[test]
    fn retired_counters_collapse_to_scalar_tally() {
        let mut sim = Simulator::new(overlay(2), net());
        sim.bootstrap_correct(&(0..12).collect::<Vec<_>>());
        for (i, v) in [2u64, 5, 9].iter().enumerate() {
            sim.schedule_fail((5 + i as Time) * MS, *v);
        }
        sim.run_until(30_000 * MS);
        let fp = sim.footprint();
        assert_eq!(fp.retired_nodes, 3);
        assert_eq!(sim.live_count(), 9);
        // totals still include the departed nodes' traffic
        let per_node = sim.control_messages_per_node();
        let live_only: u64 = sim
            .node_ids()
            .iter()
            .map(|&id| sim.node(id).unwrap().counters.control_sent)
            .sum();
        assert!(per_node * 12.0 >= live_only as f64);
    }
}
