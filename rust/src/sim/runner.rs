//! NDMP overlay simulator: drives a fleet of `NodeState` protocol engines
//! through the deterministic event queue over a pluggable `Transport`.
//! With the default in-memory backend (`SimTransport`) this is the
//! paper's "medium/large-scale simulation" substrate (§IV-A1, types 2–3)
//! for topology construction, maintenance, and churn experiments
//! (Figs. 8a–c); with `net::SchedTransport` the *same* event loop drives
//! the protocols over real localhost TCP sockets (§IV-A1, type 1).

use super::event::{EventKind, EventQueue};
use super::network::SimTransport;
use super::transport::Transport;
use crate::config::{NetConfig, OverlayConfig};
use crate::ndmp::messages::{Msg, Outgoing, Time, MS};
use crate::ndmp::node::{NodeCounters, NodeState};
use crate::topology::{correctness, NeighborSnapshot, NodeId};
use std::collections::{BTreeMap, BTreeSet};

/// A recorded correctness sample (for the Fig. 8a/8b time series).
#[derive(Debug, Clone, Copy)]
pub struct CorrectnessSample {
    pub at: Time,
    pub correctness: f64,
    pub live_nodes: usize,
}

pub struct Simulator {
    pub cfg: OverlayConfig,
    pub nodes: BTreeMap<NodeId, NodeState>,
    pub queue: EventQueue,
    pub now: Time,
    /// Message-passage backend: in-memory (`SimTransport`) or real TCP
    /// sockets (`net::SchedTransport`). Timers always stay on `queue`.
    transport: Box<dyn Transport>,
    /// Tick granularity for node timers.
    tick_period: Time,
    /// Counters of departed nodes (so message totals survive failures).
    pub retired_counters: Vec<NodeCounters>,
    pub samples: Vec<CorrectnessSample>,
    /// Messages delivered (for telemetry / debugging).
    pub delivered: u64,
    /// Nodes whose Definition-1 ring views changed since the last
    /// `take_view_changes` drain (repair, join placement, failure
    /// detection, membership churn). Consumers — e.g. the trainer's
    /// per-client neighbor cache — invalidate exactly these entries
    /// instead of re-reading every node's views per wake.
    view_changes: BTreeSet<NodeId>,
    /// Cumulative count of view-change notifications (telemetry).
    pub view_change_count: u64,
    /// When enabled (`record_deliveries`), every delivered message is
    /// traced as `(virtual arrival time, from, to)` — the conformance
    /// suite's "identical arrival timestamps" comparison view. Off by
    /// default (the trace grows with every message).
    record_deliveries: bool,
    pub delivery_log: Vec<(Time, NodeId, NodeId)>,
}

impl Simulator {
    /// A simulator on the default in-memory transport (deterministic
    /// latency model from `net`).
    pub fn new(overlay: OverlayConfig, net: NetConfig) -> Self {
        let transport = Box::new(SimTransport::new(&net));
        Self::with_transport(overlay, transport)
    }

    /// A simulator on an explicit transport backend. The event loop,
    /// protocol engines, and churn scheduling are identical on every
    /// backend; only message passage differs.
    pub fn with_transport(overlay: OverlayConfig, transport: Box<dyn Transport>) -> Self {
        let tick_period = (overlay.heartbeat_ms * 1_000) / 2;
        Self {
            cfg: overlay,
            nodes: BTreeMap::new(),
            queue: EventQueue::new(),
            now: 0,
            transport,
            tick_period: tick_period.max(1),
            retired_counters: Vec::new(),
            samples: Vec::new(),
            delivered: 0,
            view_changes: BTreeSet::new(),
            view_change_count: 0,
            record_deliveries: false,
            delivery_log: Vec::new(),
        }
    }

    /// Toggle the per-message arrival trace (see `delivery_log`).
    pub fn record_deliveries(&mut self, on: bool) {
        self.record_deliveries = on;
    }

    /// Name of the message backend (`"sim"` or `"tcp"`).
    pub fn backend(&self) -> &'static str {
        self.transport.name()
    }

    /// Drain the set of nodes whose ring views changed since the last
    /// call (see `view_changes`).
    pub fn take_view_changes(&mut self) -> Vec<NodeId> {
        let drained: Vec<NodeId> = self.view_changes.iter().copied().collect();
        self.view_changes.clear();
        drained
    }

    fn note_view_change(&mut self, id: NodeId) {
        self.view_changes.insert(id);
        self.view_change_count += 1;
    }

    /// Create a correct network of `ids` instantly (centralized shortcut
    /// used to set up the *initial* condition of churn experiments; the
    /// decentralized path is `schedule_join`). One ring sort per space —
    /// not per node — so 10k-node scenarios bootstrap in milliseconds.
    pub fn bootstrap_correct(&mut self, ids: &[NodeId]) {
        use crate::topology::fedlay::Membership;
        let mut m = Membership::new(self.cfg.spaces);
        for &id in ids {
            m.add(id);
        }
        // id -> (prev, next) per space, from a single sorted ring each
        let mut adjacency: Vec<BTreeMap<NodeId, (NodeId, NodeId)>> = Vec::new();
        for s in 0..self.cfg.spaces {
            let ring = m.ring(s);
            let n = ring.len();
            let mut tab = BTreeMap::new();
            if n >= 2 {
                for pos in 0..n {
                    tab.insert(
                        ring[pos].id,
                        (ring[(pos + n - 1) % n].id, ring[(pos + 1) % n].id),
                    );
                }
            }
            adjacency.push(tab);
        }
        for &id in ids {
            let mut st = NodeState::new(id, self.cfg.clone(), self.now);
            st.bootstrap_first();
            for (s, tab) in adjacency.iter().enumerate() {
                if let Some(&(prev, next)) = tab.get(&id) {
                    st.views[s].prev = Some(prev);
                    st.views[s].next = Some(next);
                }
            }
            // seed the peer table from the views
            for s in 0..self.cfg.spaces {
                if let Some(p) = st.views[s].prev {
                    st.handle(p, Msg::Heartbeat, self.now);
                }
                if let Some(nx) = st.views[s].next {
                    st.handle(nx, Msg::Heartbeat, self.now);
                }
            }
            // zero the counters: bootstrap is not protocol traffic
            st.counters = NodeCounters::default();
            self.transport.open(id).expect("transport endpoint");
            self.nodes.insert(id, st);
            self.note_view_change(id);
            self.queue.push(self.now + 1, EventKind::Tick { node: id });
        }
    }

    /// Start an empty network with a single node.
    pub fn bootstrap_single(&mut self, id: NodeId) {
        let mut st = NodeState::new(id, self.cfg.clone(), self.now);
        st.bootstrap_first();
        self.transport.open(id).expect("transport endpoint");
        self.nodes.insert(id, st);
        self.note_view_change(id);
        self.queue.push(self.now + 1, EventKind::Tick { node: id });
    }

    pub fn schedule_join(&mut self, at: Time, node: NodeId, bootstrap: NodeId) {
        self.queue.push(at, EventKind::Join { node, bootstrap });
    }

    pub fn schedule_fail(&mut self, at: Time, node: NodeId) {
        self.queue.push(at, EventKind::Fail { node });
    }

    pub fn schedule_leave(&mut self, at: Time, node: NodeId) {
        self.queue.push(at, EventKind::Leave { node });
    }

    pub fn schedule_snapshot(&mut self, at: Time) {
        self.queue.push(at, EventKind::Snapshot { tag: 0 });
    }

    fn dispatch(&mut self, from: NodeId, outs: Vec<Outgoing>) {
        for o in outs {
            if o.to == from {
                continue;
            }
            // Queue-scheduled backends answer with a delivery time; wire
            // backends carry the bytes themselves and we poll (`pump`).
            if let Some(at) = self.transport.send(self.now, from, o.to, &o.msg) {
                self.queue.push(
                    at,
                    EventKind::Deliver {
                        from,
                        to: o.to,
                        msg: o.msg,
                    },
                );
            }
        }
    }

    /// Collect frames the transport carried out-of-band (socket
    /// backends) and schedule each as a `Deliver` event at its stamped
    /// virtual arrival time — the same queue path the in-memory backend
    /// takes, so both backends process deliveries in the identical
    /// order. A no-op on the in-memory backend.
    ///
    /// `poll` returns arrivals in (due time, send order); pushing them
    /// in that order reproduces the in-memory backend's queue insertion
    /// order for equal-time ties. Stamps are always in the future of the
    /// sending instant (delays are >= 1 µs); the `max` only guards
    /// frames a slow loopback surfaced after their due instant, which
    /// are delivered as soon as possible instead of rewinding the clock.
    fn pump(&mut self) {
        if self.transport.idle() {
            return;
        }
        for a in self.transport.poll() {
            self.queue.push(
                a.at.max(self.now),
                EventKind::Deliver {
                    from: a.from,
                    to: a.to,
                    msg: a.msg,
                },
            );
        }
    }

    /// Current neighbor-set snapshot of all live nodes.
    pub fn snapshot(&self) -> NeighborSnapshot {
        self.nodes
            .iter()
            .map(|(&id, st)| (id, st.neighbor_ids()))
            .collect()
    }

    /// Ring-adjacency snapshot (Definition-1 views only, excluding
    /// incidental routed-traffic peers). Two converged backends must
    /// agree on this exactly — the conformance-test comparison view.
    pub fn ring_snapshot(&self) -> NeighborSnapshot {
        self.nodes
            .iter()
            .map(|(&id, st)| (id, st.ring_neighbor_ids()))
            .collect()
    }

    /// The live overlay as an undirected graph (indices follow sorted id
    /// order; the second value maps graph index -> node id).
    pub fn live_graph(&self) -> (crate::graph::Graph, Vec<NodeId>) {
        correctness::graph_from_snapshot(&self.snapshot())
    }

    pub fn correctness(&self) -> f64 {
        correctness(&self.snapshot(), self.cfg.spaces)
    }

    /// Total control messages sent per live+retired node.
    pub fn control_messages_per_node(&self) -> f64 {
        let live: u64 = self.nodes.values().map(|n| n.counters.control_sent).sum();
        let retired: u64 = self.retired_counters.iter().map(|c| c.control_sent).sum();
        let count = self.nodes.len() + self.retired_counters.len();
        if count == 0 {
            0.0
        } else {
            (live + retired) as f64 / count as f64
        }
    }

    /// Run until `deadline` (inclusive) or the queue drains. Timer and
    /// churn events pop from the deterministic queue; between events any
    /// wire-carried messages are pumped in.
    pub fn run_until(&mut self, deadline: Time) {
        self.pump();
        while let Some(t) = self.queue.peek_time() {
            if t > deadline {
                break;
            }
            let ev = self.queue.pop().unwrap();
            self.now = ev.at;
            match ev.kind {
                EventKind::Deliver { from, to, msg } => {
                    // Messages to dead nodes vanish (crash-fail model)
                    // *before* counting: the wire backend never has a
                    // frame for them (the send is dropped at the closed
                    // endpoint), so counting them here would make
                    // `delivered` and the delivery log diverge between
                    // backends.
                    let Some(node) = self.nodes.get_mut(&to) else {
                        continue;
                    };
                    self.delivered += 1;
                    if self.record_deliveries {
                        self.delivery_log.push((self.now, from, to));
                    }
                    let stamp = node.view_stamp();
                    let outs = node.handle(from, msg, self.now);
                    if node.view_stamp() != stamp {
                        self.note_view_change(to);
                    }
                    self.dispatch(to, outs);
                }
                EventKind::Tick { node } => {
                    let Some(st) = self.nodes.get_mut(&node) else {
                        continue;
                    };
                    let stamp = st.view_stamp();
                    let outs = st.tick(self.now);
                    if st.view_stamp() != stamp {
                        self.note_view_change(node);
                    }
                    // push the next tick *before* dispatching: the wire
                    // backend's deliveries enter the queue after the
                    // event (in `pump`), so a uniform tick-first order
                    // keeps equal-time tie-breaking identical on both
                    // backends
                    self.queue
                        .push(self.now + self.tick_period, EventKind::Tick { node });
                    self.dispatch(node, outs);
                }
                EventKind::Join { node, bootstrap } => {
                    if self.nodes.contains_key(&node) || !self.nodes.contains_key(&bootstrap) {
                        continue;
                    }
                    if self.transport.open(node).is_err() {
                        continue; // endpoint unavailable: the join is lost
                    }
                    let mut st = NodeState::new(node, self.cfg.clone(), self.now);
                    let outs = st.start_join(bootstrap, self.now);
                    self.nodes.insert(node, st);
                    self.note_view_change(node);
                    // tick before dispatch: see the Tick arm
                    self.queue
                        .push(self.now + self.tick_period, EventKind::Tick { node });
                    self.dispatch(node, outs);
                }
                EventKind::Fail { node } => {
                    if let Some(st) = self.nodes.remove(&node) {
                        self.retired_counters.push(st.counters);
                        self.note_view_change(node);
                        self.transport.close(node);
                    }
                }
                EventKind::Leave { node } => {
                    if let Some(mut st) = self.nodes.remove(&node) {
                        let outs = st.start_leave();
                        self.retired_counters.push(st.counters);
                        self.note_view_change(node);
                        // flush the leave notices, then tear the endpoint
                        // down — in-flight messages to it vanish, exactly
                        // like the in-memory dead-node rule.
                        self.dispatch(node, outs);
                        self.transport.close(node);
                    }
                }
                EventKind::Snapshot { .. } => {
                    let c = self.correctness();
                    self.samples.push(CorrectnessSample {
                        at: self.now,
                        correctness: c,
                        live_nodes: self.nodes.len(),
                    });
                }
            }
            self.pump();
        }
        self.now = self.now.max(deadline);
        self.pump();
    }

    /// Convenience: run until correctness reaches `threshold` or `deadline`
    /// passes; returns the time correctness first reached the threshold.
    pub fn run_until_correct(
        &mut self,
        threshold: f64,
        deadline: Time,
        check_every: Time,
    ) -> Option<Time> {
        loop {
            let next = (self.now + check_every).min(deadline);
            self.run_until(next);
            if self.correctness() >= threshold {
                return Some(self.now);
            }
            if self.now >= deadline {
                return None;
            }
        }
    }
}

/// Build a network of `n` nodes purely through the decentralized join
/// protocol, one join per `spacing` (sequential joins, §III-B1).
pub fn grow_network(
    overlay: OverlayConfig,
    net: NetConfig,
    n: usize,
    spacing: Time,
) -> Simulator {
    let mut sim = Simulator::new(overlay, net);
    sim.bootstrap_single(0);
    for i in 1..n as NodeId {
        // join via a deterministic pseudo-random existing node
        let bootstrap = i.wrapping_mul(0x9E37_79B9_7F4A_7C15) % i;
        sim.schedule_join(sim.now + i * spacing, i, bootstrap);
    }
    // run past the last scheduled join first — checking correctness any
    // earlier would "pass" on a partially-grown (but locally correct)
    // network — then settle until Definition-1 correctness over all n.
    sim.run_until(n as Time * spacing + 1);
    let deadline = n as Time * spacing + 60_000 * MS;
    sim.run_until_correct(1.0, deadline, 2_000 * MS);
    debug_assert_eq!(sim.nodes.len(), n, "grow_network lost joiners");
    sim
}

#[cfg(test)]
mod tests {
    use super::*;

    fn overlay(spaces: usize) -> OverlayConfig {
        OverlayConfig {
            spaces,
            heartbeat_ms: 500,
            failure_multiple: 3,
            repair_probe_ms: 2_000,
        }
    }

    fn net() -> NetConfig {
        NetConfig {
            latency_ms: 50.0,
            jitter: 0.2,
            seed: 5,
        }
    }

    #[test]
    fn bootstrap_correct_is_correct() {
        let mut sim = Simulator::new(overlay(3), net());
        let ids: Vec<NodeId> = (0..50).collect();
        sim.bootstrap_correct(&ids);
        assert!((sim.correctness() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sequential_joins_converge_to_correct() {
        let sim = grow_network(overlay(2), net(), 20, 2_000 * MS);
        assert!(
            sim.correctness() > 0.999,
            "correctness {}",
            sim.correctness()
        );
    }

    #[test]
    fn live_graph_matches_bootstrap_topology() {
        let mut sim = Simulator::new(overlay(3), net());
        sim.bootstrap_correct(&(0..30).collect::<Vec<_>>());
        let (g, ids) = sim.live_graph();
        assert_eq!(ids.len(), 30);
        assert!(g.max_degree() <= 6, "degree bound 2L violated");
        assert!(crate::graph::traversal::is_connected(&g));
    }

    #[test]
    fn single_failure_recovers() {
        let mut sim = Simulator::new(overlay(2), net());
        let ids: Vec<NodeId> = (0..30).collect();
        sim.bootstrap_correct(&ids);
        sim.schedule_fail(10 * MS, 7);
        // allow detection (3 * 500ms) + repair routing
        let t = sim.run_until_correct(1.0, 60_000 * MS, 500 * MS);
        assert!(t.is_some(), "failure not repaired; c={}", sim.correctness());
    }

    #[test]
    fn graceful_leave_repairs_instantly() {
        let mut sim = Simulator::new(overlay(2), net());
        let ids: Vec<NodeId> = (0..25).collect();
        sim.bootstrap_correct(&ids);
        sim.schedule_leave(10 * MS, 11);
        let t = sim.run_until_correct(1.0, 20_000 * MS, 100 * MS);
        assert!(t.is_some(), "leave not repaired; c={}", sim.correctness());
        assert!(!sim.nodes.contains_key(&11));
    }

    #[test]
    fn concurrent_joins_converge() {
        let mut sim = Simulator::new(overlay(2), net());
        let ids: Vec<NodeId> = (0..20).collect();
        sim.bootstrap_correct(&ids);
        // 10 concurrent joins at the same instant through random nodes
        for j in 100..110u64 {
            sim.schedule_join(10 * MS, j, j % 20);
        }
        let t = sim.run_until_correct(1.0, 120_000 * MS, 1_000 * MS);
        assert!(
            t.is_some(),
            "concurrent joins did not converge; c={}",
            sim.correctness()
        );
        assert_eq!(sim.nodes.len(), 30);
    }

    #[test]
    fn concurrent_failures_recover() {
        let mut sim = Simulator::new(overlay(3), net());
        let ids: Vec<NodeId> = (0..40).collect();
        sim.bootstrap_correct(&ids);
        for f in [3u64, 9, 21, 33] {
            sim.schedule_fail(10 * MS, f);
        }
        let t = sim.run_until_correct(1.0, 180_000 * MS, 1_000 * MS);
        assert!(
            t.is_some(),
            "concurrent failures did not recover; c={}",
            sim.correctness()
        );
        assert_eq!(sim.nodes.len(), 36);
    }

    #[test]
    fn view_changes_track_churn_and_go_quiet() {
        use crate::sim::scenario::quiesce;
        let mut sim = Simulator::new(overlay(2), net());
        sim.bootstrap_correct(&(0..20).collect::<Vec<_>>());
        // bootstrap notifies every node once
        let boot: Vec<NodeId> = sim.take_view_changes();
        assert_eq!(boot.len(), 20);
        sim.schedule_fail(10 * MS, 3);
        // run past the failure instant, then settle to the exact ideal
        // rings (stronger than correctness 1.0: no residual adoptions
        // left to fire during the quiet window)
        sim.run_until(1_000 * MS);
        let t = quiesce(&mut sim, 120_000 * MS, 500 * MS);
        assert!(t.is_some(), "failure not repaired: {}", sim.correctness());
        let changed = sim.take_view_changes();
        // the failed node and (at least) its ring neighbors changed views
        assert!(changed.contains(&3));
        assert!(changed.len() >= 3, "repair should touch neighbors: {changed:?}");
        assert!(sim.view_change_count >= changed.len() as u64);
        // a converged, churn-free network stays quiet
        let quiet_from = sim.now;
        sim.run_until(quiet_from + 20_000 * MS);
        assert!(
            sim.take_view_changes().is_empty(),
            "steady-state heartbeats must not emit view changes"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut sim = Simulator::new(overlay(2), net());
            sim.bootstrap_correct(&(0..15).collect::<Vec<_>>());
            sim.schedule_fail(5 * MS, 3);
            sim.schedule_join(6 * MS, 99, 1);
            sim.run_until(30_000 * MS);
            (sim.correctness(), sim.delivered, sim.control_messages_per_node())
        };
        assert_eq!(run(), run());
    }
}
