//! Generic deterministic scheduler: a priority queue of timestamped
//! events, generic over the event-kind type.
//!
//! This is the single time substrate of the repo. The NDMP overlay
//! simulator instantiates it with `sim::EventKind` (message deliveries,
//! timers, churn), the DFL trainer instantiates it with
//! `dfl::TrainEvent` (client wake-ups, synchronous rounds, accuracy
//! samples, churn injections), and the real-TCP node reactor
//! (`net::client_node`) instantiates it with its timer kinds — all three
//! pop from the same kind of heap and therefore share the same
//! determinism guarantee: ties at equal timestamps break on a monotone
//! sequence number, so runs are exactly reproducible regardless of the
//! order in which events were discovered and pushed.
//!
//! `push` returns an `EventId` that `cancel` accepts: cancelled events
//! are tombstoned and silently skipped by `pop`/`peek_time`, so callers
//! can de-schedule timers without rebuilding the heap.

use crate::ndmp::messages::Time;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashSet};

/// Identifier of a scheduled event (its sequence number), used to cancel
/// it before it fires. Ids are unique per scheduler and never reused.
pub type EventId = u64;

/// A scheduled event: fires at `at`; `seq` is the push order and breaks
/// timestamp ties deterministically.
#[derive(Debug, Clone)]
pub struct Scheduled<K> {
    pub at: Time,
    pub seq: u64,
    pub kind: K,
}

impl<K> PartialEq for Scheduled<K> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<K> Eq for Scheduled<K> {}

impl<K> PartialOrd for Scheduled<K> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<K> Ord for Scheduled<K> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first, then
        // lowest-seq-first among equal timestamps.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic event queue over an arbitrary event-kind type.
#[derive(Debug)]
pub struct Scheduler<K> {
    heap: BinaryHeap<Scheduled<K>>,
    seq: u64,
    /// Ids currently live in the heap (pushed, not yet popped/cancelled).
    pending: HashSet<u64>,
    /// Cancelled ids whose heap entries have not been reaped yet.
    cancelled: HashSet<u64>,
}

impl<K> Default for Scheduler<K> {
    fn default() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
            pending: HashSet::new(),
            cancelled: HashSet::new(),
        }
    }
}

impl<K> Scheduler<K> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `kind` at absolute time `at`; the returned id can cancel
    /// the event before it fires. O(log n).
    pub fn push(&mut self, at: Time, kind: K) -> EventId {
        let seq = self.seq;
        self.seq += 1;
        self.pending.insert(seq);
        self.heap.push(Scheduled { at, seq, kind });
        seq
    }

    /// Cancel a pending event. Returns `true` if it was still pending;
    /// cancelling an already-fired or already-cancelled id is a no-op.
    pub fn cancel(&mut self, id: EventId) -> bool {
        if self.pending.remove(&id) {
            self.cancelled.insert(id);
            true
        } else {
            false
        }
    }

    /// Pop the earliest live event (ties in push order), skipping
    /// cancelled tombstones. O(log n) amortized.
    pub fn pop(&mut self) -> Option<Scheduled<K>> {
        while let Some(e) = self.heap.pop() {
            if self.cancelled.remove(&e.seq) {
                continue;
            }
            self.pending.remove(&e.seq);
            return Some(e);
        }
        None
    }

    /// Timestamp of the next live event without popping it. Reaps any
    /// cancelled tombstones sitting at the top of the heap.
    pub fn peek_time(&mut self) -> Option<Time> {
        loop {
            let (at, seq) = match self.heap.peek() {
                None => return None,
                Some(e) => (e.at, e.seq),
            };
            if self.cancelled.contains(&seq) {
                self.heap.pop();
                self.cancelled.remove(&seq);
            } else {
                return Some(at);
            }
        }
    }

    /// Number of live (non-cancelled) pending events.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::collections::{BTreeMap, VecDeque};

    #[test]
    fn pops_in_time_order() {
        let mut q: Scheduler<&'static str> = Scheduler::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        let order: Vec<(Time, &str)> =
            std::iter::from_fn(|| q.pop().map(|e| (e.at, e.kind))).collect();
        assert_eq!(order, vec![(10, "a"), (20, "b"), (30, "c")]);
    }

    #[test]
    fn same_timestamp_pops_in_insertion_order() {
        let mut q: Scheduler<u64> = Scheduler::new();
        for tag in 0..100u64 {
            q.push(5, tag);
        }
        let tags: Vec<u64> = std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(tags, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn ties_break_by_seq_regardless_of_push_pattern() {
        // Interleave pushes of two timestamps in several patterns; within
        // each timestamp the pop order must always equal the push order.
        for pattern in 0..8u64 {
            let mut q: Scheduler<(Time, u64)> = Scheduler::new();
            let mut per_time: std::collections::BTreeMap<Time, Vec<u64>> = Default::default();
            for i in 0..50u64 {
                // deterministic pseudo-random interleaving of t=7 and t=3
                let t = if (i.wrapping_mul(pattern + 1) ^ i) % 3 == 0 { 7 } else { 3 };
                q.push(t, (t, i));
                per_time.entry(t).or_default().push(i);
            }
            let mut popped: std::collections::BTreeMap<Time, Vec<u64>> = Default::default();
            let mut last_t = 0;
            while let Some(e) = q.pop() {
                assert!(e.at >= last_t, "time went backwards");
                last_t = e.at;
                popped.entry(e.at).or_default().push(e.kind.1);
            }
            assert_eq!(popped, per_time, "pattern {pattern}");
        }
    }

    #[test]
    fn interleaved_push_pop_keeps_seq_monotone() {
        let mut q: Scheduler<u64> = Scheduler::new();
        q.push(5, 0);
        q.push(5, 1);
        assert_eq!(q.pop().unwrap().kind, 0);
        // pushes after a pop still order after the earlier survivors
        q.push(5, 2);
        q.push(5, 3);
        let rest: Vec<u64> = std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(rest, vec![1, 2, 3]);
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
    }

    // ------------------------------------------------------------------
    // Property tests: random event batches against a reference model
    // ------------------------------------------------------------------

    /// Random push batches, drained completely: pop times never decrease
    /// and ties pop FIFO per timestamp, for many seeds.
    #[test]
    fn prop_random_batches_preserve_time_order_and_fifo_ties() {
        for seed in 0..32u64 {
            let mut rng = Rng::new(seed ^ 0x5C4ED);
            let mut q: Scheduler<u64> = Scheduler::new();
            let mut pushed: BTreeMap<Time, Vec<u64>> = BTreeMap::new();
            let n = 1 + rng.index(200);
            for tag in 0..n as u64 {
                let t = rng.below(32) as Time;
                q.push(t, tag);
                pushed.entry(t).or_default().push(tag);
            }
            assert_eq!(q.len(), n);
            let mut popped: BTreeMap<Time, Vec<u64>> = BTreeMap::new();
            let mut last = 0;
            while let Some(e) = q.pop() {
                assert!(e.at >= last, "seed {seed}: time went backwards");
                last = e.at;
                popped.entry(e.at).or_default().push(e.kind);
            }
            assert_eq!(popped, pushed, "seed {seed}");
            assert!(q.is_empty());
        }
    }

    /// Random interleavings of push/pop against an exact reference model
    /// (a time-ordered map of FIFO queues): every pop must return the
    /// front of the earliest-time queue.
    #[test]
    fn prop_interleaved_ops_match_reference_model() {
        for seed in 0..32u64 {
            let mut rng = Rng::new(seed ^ 0x1F0);
            let mut q: Scheduler<u64> = Scheduler::new();
            let mut model: BTreeMap<Time, VecDeque<u64>> = BTreeMap::new();
            let mut tag = 0u64;
            for _ in 0..400 {
                if rng.chance(0.6) {
                    let t = rng.below(24) as Time;
                    q.push(t, tag);
                    model.entry(t).or_default().push_back(tag);
                    tag += 1;
                } else {
                    let want = model.iter_mut().next().map(|(&t, fifo)| {
                        let v = fifo.pop_front().unwrap();
                        (t, v)
                    });
                    if let Some((t, _)) = want {
                        if model[&t].is_empty() {
                            model.remove(&t);
                        }
                    }
                    let got = q.pop().map(|e| (e.at, e.kind));
                    assert_eq!(got, want, "seed {seed}");
                }
            }
            // drain what is left
            while let Some(e) = q.pop() {
                let (&t, fifo) = model.iter_mut().next().expect("model drained early");
                assert_eq!((e.at, e.kind), (t, fifo.pop_front().unwrap()));
                if fifo.is_empty() {
                    model.remove(&t);
                }
            }
            assert!(model.is_empty(), "seed {seed}: scheduler drained early");
        }
    }

    /// Random cancel interleavings: cancel-then-fire never panics, a
    /// cancelled event never pops, and double-cancel / cancel-after-pop
    /// report `false`.
    #[test]
    fn prop_cancel_then_fire_never_panics() {
        for seed in 0..32u64 {
            let mut rng = Rng::new(seed ^ 0xCA7CE1);
            let mut q: Scheduler<u64> = Scheduler::new();
            let mut model: BTreeMap<Time, VecDeque<(EventId, u64)>> = BTreeMap::new();
            let mut live: Vec<EventId> = Vec::new();
            let mut gone: Vec<EventId> = Vec::new();
            let mut tag = 0u64;
            for _ in 0..400 {
                let r = rng.next_f64();
                if r < 0.5 {
                    let t = rng.below(24) as Time;
                    let id = q.push(t, tag);
                    model.entry(t).or_default().push_back((id, tag));
                    live.push(id);
                    tag += 1;
                } else if r < 0.75 && !live.is_empty() {
                    let id = live.swap_remove(rng.index(live.len()));
                    assert!(q.cancel(id), "seed {seed}: live cancel failed");
                    for fifo in model.values_mut() {
                        fifo.retain(|&(i, _)| i != id);
                    }
                    model.retain(|_, fifo| !fifo.is_empty());
                    gone.push(id);
                } else if r < 0.85 && !gone.is_empty() {
                    // double-cancel / cancel-after-pop is a reported no-op
                    let id = gone[rng.index(gone.len())];
                    assert!(!q.cancel(id), "seed {seed}: dead cancel fired");
                } else {
                    let want = model.iter_mut().next().map(|(&t, fifo)| {
                        let (id, v) = fifo.pop_front().unwrap();
                        (t, id, v)
                    });
                    if let Some((t, _, _)) = want {
                        if model[&t].is_empty() {
                            model.remove(&t);
                        }
                    }
                    let got = q.pop().map(|e| (e.at, e.seq, e.kind));
                    assert_eq!(got, want, "seed {seed}");
                    if let Some((_, id, _)) = got {
                        live.retain(|&i| i != id);
                        gone.push(id);
                    }
                }
                // peek_time must always agree with the model's earliest
                assert_eq!(
                    q.peek_time(),
                    model.keys().next().copied(),
                    "seed {seed}"
                );
                assert_eq!(
                    q.len(),
                    model.values().map(|f| f.len()).sum::<usize>(),
                    "seed {seed}"
                );
            }
        }
    }

    #[test]
    fn cancel_skips_event_and_preserves_order() {
        let mut q: Scheduler<&'static str> = Scheduler::new();
        let _a = q.push(10, "a");
        let b = q.push(10, "b");
        let _c = q.push(20, "c");
        assert!(q.cancel(b));
        assert!(!q.cancel(b), "double cancel must be a no-op");
        assert_eq!(q.len(), 2);
        assert_eq!(q.peek_time(), Some(10));
        assert_eq!(q.pop().unwrap().kind, "a");
        assert_eq!(q.pop().unwrap().kind, "c");
        assert!(q.pop().is_none());
        // cancelling an already-popped id reports false, never panics
        assert!(!q.cancel(0));
        assert!(!q.cancel(999));
    }
}
