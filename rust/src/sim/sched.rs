//! Generic deterministic scheduler: a priority queue of timestamped
//! events, generic over the event-kind type.
//!
//! This is the single time substrate of the repo. The NDMP overlay
//! simulator instantiates it with `sim::EventKind` (message deliveries,
//! timers, churn) and the DFL trainer instantiates it with
//! `dfl::TrainEvent` (client wake-ups, synchronous rounds, accuracy
//! samples, churn injections) — both halves of the unified engine pop
//! from the same kind of heap and therefore share the same determinism
//! guarantee: ties at equal timestamps break on a monotone sequence
//! number, so runs are exactly reproducible regardless of the order in
//! which events were discovered and pushed.

use crate::ndmp::messages::Time;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A scheduled event: fires at `at`; `seq` is the push order and breaks
/// timestamp ties deterministically.
#[derive(Debug, Clone)]
pub struct Scheduled<K> {
    pub at: Time,
    pub seq: u64,
    pub kind: K,
}

impl<K> PartialEq for Scheduled<K> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<K> Eq for Scheduled<K> {}

impl<K> PartialOrd for Scheduled<K> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<K> Ord for Scheduled<K> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first, then
        // lowest-seq-first among equal timestamps.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic event queue over an arbitrary event-kind type.
#[derive(Debug)]
pub struct Scheduler<K> {
    heap: BinaryHeap<Scheduled<K>>,
    seq: u64,
}

impl<K> Default for Scheduler<K> {
    fn default() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }
}

impl<K> Scheduler<K> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `kind` at absolute time `at`. O(log n).
    pub fn push(&mut self, at: Time, kind: K) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { at, seq, kind });
    }

    /// Pop the earliest event (ties in push order). O(log n).
    pub fn pop(&mut self) -> Option<Scheduled<K>> {
        self.heap.pop()
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q: Scheduler<&'static str> = Scheduler::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        let order: Vec<(Time, &str)> =
            std::iter::from_fn(|| q.pop().map(|e| (e.at, e.kind))).collect();
        assert_eq!(order, vec![(10, "a"), (20, "b"), (30, "c")]);
    }

    #[test]
    fn same_timestamp_pops_in_insertion_order() {
        let mut q: Scheduler<u64> = Scheduler::new();
        for tag in 0..100u64 {
            q.push(5, tag);
        }
        let tags: Vec<u64> = std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(tags, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn ties_break_by_seq_regardless_of_push_pattern() {
        // Interleave pushes of two timestamps in several patterns; within
        // each timestamp the pop order must always equal the push order.
        for pattern in 0..8u64 {
            let mut q: Scheduler<(Time, u64)> = Scheduler::new();
            let mut per_time: std::collections::BTreeMap<Time, Vec<u64>> = Default::default();
            for i in 0..50u64 {
                // deterministic pseudo-random interleaving of t=7 and t=3
                let t = if (i.wrapping_mul(pattern + 1) ^ i) % 3 == 0 { 7 } else { 3 };
                q.push(t, (t, i));
                per_time.entry(t).or_default().push(i);
            }
            let mut popped: std::collections::BTreeMap<Time, Vec<u64>> = Default::default();
            let mut last_t = 0;
            while let Some(e) = q.pop() {
                assert!(e.at >= last_t, "time went backwards");
                last_t = e.at;
                popped.entry(e.at).or_default().push(e.kind.1);
            }
            assert_eq!(popped, per_time, "pattern {pattern}");
        }
    }

    #[test]
    fn interleaved_push_pop_keeps_seq_monotone() {
        let mut q: Scheduler<u64> = Scheduler::new();
        q.push(5, 0);
        q.push(5, 1);
        assert_eq!(q.pop().unwrap().kind, 0);
        // pushes after a pop still order after the earlier survivors
        q.push(5, 2);
        q.push(5, 3);
        let rest: Vec<u64> = std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(rest, vec![1, 2, 3]);
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
    }
}
