//! Generic deterministic scheduler: a priority queue of timestamped
//! events, generic over the event-kind type.
//!
//! This is the single time substrate of the repo. The NDMP overlay
//! simulator instantiates it with `sim::EventKind` (message deliveries,
//! timers, churn), the DFL trainer instantiates it with
//! `dfl::TrainEvent` (client wake-ups, synchronous rounds, accuracy
//! samples, churn injections), and the real-TCP node reactor
//! (`net::client_node`) instantiates it with its timer kinds — all three
//! pop from the same kind of heap and therefore share the same
//! determinism guarantee: ties at equal timestamps break on a monotone
//! sequence number, so runs are exactly reproducible regardless of the
//! order in which events were discovered and pushed.
//!
//! `push` returns an `EventId` that `cancel` accepts: cancelled events
//! are tombstoned and silently skipped by `pop`/`peek_time`, so callers
//! can de-schedule timers without rebuilding the heap.

use crate::ndmp::messages::Time;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// Identifier of a scheduled event (its sequence number), used to cancel
/// it before it fires. Ids are unique per scheduler and never reused.
pub type EventId = u64;

/// A scheduled event: fires at `at`; `seq` is the push order and breaks
/// timestamp ties deterministically.
#[derive(Debug, Clone)]
pub struct Scheduled<K> {
    pub at: Time,
    pub seq: u64,
    pub kind: K,
}

impl<K> PartialEq for Scheduled<K> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<K> Eq for Scheduled<K> {}

impl<K> PartialOrd for Scheduled<K> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<K> Ord for Scheduled<K> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first, then
        // lowest-seq-first among equal timestamps.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Per-seq lifecycle flags as a pair of windowed bitmaps sharing one
/// base offset. Sequence numbers are allocated monotonically, so the
/// live ids cluster in a narrow moving window: one `pending` bit and one
/// `cancelled` bit per seq in that window replace the two `HashSet<u64>`
/// the scheduler used to rehash on every push/pop/cancel. The window's
/// front advances (both deques pop a word, `base` bumps) whenever the
/// front 64 seqs are fully resolved, so memory is bounded by the live
/// seq *span*, not by history.
///
/// Invariant: any seq still physically in the heap has exactly one of
/// its two bits set (pending until popped or cancelled; cancelled until
/// its tombstone is reaped), so `base` can never advance past it.
#[derive(Debug, Default)]
struct SeqFlags {
    /// Word index (seq >> 6) of the front of both deques.
    base: u64,
    pending: VecDeque<u64>,
    cancelled: VecDeque<u64>,
    live: usize,
}

impl SeqFlags {
    #[inline]
    fn split(&self, seq: u64) -> Option<(usize, u64)> {
        let word = seq >> 6;
        if word < self.base {
            return None; // fully resolved window
        }
        Some(((word - self.base) as usize, 1u64 << (seq & 63)))
    }

    fn mark_pending(&mut self, seq: u64) {
        let (idx, bit) = self.split(seq).expect("seq below resolved window");
        if idx >= self.pending.len() {
            self.pending.resize(idx + 1, 0);
            self.cancelled.resize(idx + 1, 0);
        }
        debug_assert_eq!(self.pending[idx] & bit, 0, "seq pushed twice");
        self.pending[idx] |= bit;
        self.live += 1;
    }

    /// pending -> cancelled; `false` if the seq is not currently pending.
    fn cancel(&mut self, seq: u64) -> bool {
        let Some((idx, bit)) = self.split(seq) else {
            return false;
        };
        if idx >= self.pending.len() || self.pending[idx] & bit == 0 {
            return false;
        }
        self.pending[idx] &= !bit;
        self.cancelled[idx] |= bit;
        self.live -= 1;
        true
    }

    #[inline]
    fn is_cancelled(&self, seq: u64) -> bool {
        match self.split(seq) {
            Some((idx, bit)) => idx < self.cancelled.len() && self.cancelled[idx] & bit != 0,
            None => false,
        }
    }

    /// Resolve a seq that just left the heap (either popped live or
    /// reaped as a tombstone), then let the window front advance past
    /// fully-resolved words.
    fn resolve(&mut self, seq: u64, was_cancelled: bool) {
        let (idx, bit) = self.split(seq).expect("heap seq below resolved window");
        if was_cancelled {
            self.cancelled[idx] &= !bit;
        } else {
            debug_assert_ne!(self.pending[idx] & bit, 0);
            self.pending[idx] &= !bit;
            self.live -= 1;
        }
        while let (Some(&0), Some(&0)) = (self.pending.front(), self.cancelled.front()) {
            self.pending.pop_front();
            self.cancelled.pop_front();
            self.base += 1;
        }
    }

    /// Bitmap words currently held (both maps), for footprint assertions.
    fn words(&self) -> usize {
        self.pending.len() + self.cancelled.len()
    }
}

/// Deterministic event queue over an arbitrary event-kind type.
#[derive(Debug)]
pub struct Scheduler<K> {
    heap: BinaryHeap<Scheduled<K>>,
    seq: u64,
    flags: SeqFlags,
}

impl<K> Default for Scheduler<K> {
    fn default() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
            flags: SeqFlags::default(),
        }
    }
}

impl<K> Scheduler<K> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `kind` at absolute time `at`; the returned id can cancel
    /// the event before it fires. O(log n).
    pub fn push(&mut self, at: Time, kind: K) -> EventId {
        let seq = self.seq;
        self.seq += 1;
        self.flags.mark_pending(seq);
        self.heap.push(Scheduled { at, seq, kind });
        seq
    }

    /// Schedule with an externally-assigned sequence number (must be >=
    /// every id this queue has handed out). The sharded engine routes
    /// events from one *global* seq counter into per-shard queues, so
    /// ties at equal timestamps still break in global emission order.
    pub fn push_at_seq(&mut self, at: Time, seq: u64, kind: K) -> EventId {
        assert!(seq >= self.seq, "seq {seq} reused (next is {})", self.seq);
        self.seq = seq + 1;
        self.flags.mark_pending(seq);
        self.heap.push(Scheduled { at, seq, kind });
        seq
    }

    /// Cancel a pending event. Returns `true` if it was still pending;
    /// cancelling an already-fired or already-cancelled id is a no-op.
    pub fn cancel(&mut self, id: EventId) -> bool {
        id < self.seq && self.flags.cancel(id)
    }

    /// Pop the earliest live event (ties in push order), skipping
    /// cancelled tombstones. O(log n) amortized.
    pub fn pop(&mut self) -> Option<Scheduled<K>> {
        while let Some(e) = self.heap.pop() {
            if self.flags.is_cancelled(e.seq) {
                self.flags.resolve(e.seq, true);
                continue;
            }
            self.flags.resolve(e.seq, false);
            return Some(e);
        }
        None
    }

    /// Timestamp of the next live event without popping it. Reaps any
    /// cancelled tombstones sitting at the top of the heap.
    pub fn peek_time(&mut self) -> Option<Time> {
        self.peek().map(|e| e.at)
    }

    /// The next live event without popping it (tombstones at the top are
    /// reaped first). Lets batch loops inspect `(at, seq)` before
    /// deciding whether to consume.
    pub fn peek(&mut self) -> Option<&Scheduled<K>> {
        loop {
            let seq = match self.heap.peek() {
                None => return None,
                Some(e) => e.seq,
            };
            if self.flags.is_cancelled(seq) {
                self.heap.pop();
                self.flags.resolve(seq, true);
            } else {
                // borrow-checker two-phase: re-peek now that we keep it
                return self.heap.peek();
            }
        }
    }

    /// Number of live (non-cancelled) pending events.
    pub fn len(&self) -> usize {
        self.flags.live
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Next sequence number this queue would allocate.
    pub fn next_seq(&self) -> u64 {
        self.seq
    }

    /// Bytes of cancel/pending bookkeeping currently held. The windowed
    /// bitmaps must stay proportional to the live seq span — the
    /// footprint regression test pins this under sustained churn.
    pub fn bookkeeping_bytes(&self) -> usize {
        self.flags.words() * std::mem::size_of::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::collections::{BTreeMap, VecDeque};

    #[test]
    fn pops_in_time_order() {
        let mut q: Scheduler<&'static str> = Scheduler::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        let order: Vec<(Time, &str)> =
            std::iter::from_fn(|| q.pop().map(|e| (e.at, e.kind))).collect();
        assert_eq!(order, vec![(10, "a"), (20, "b"), (30, "c")]);
    }

    #[test]
    fn same_timestamp_pops_in_insertion_order() {
        let mut q: Scheduler<u64> = Scheduler::new();
        for tag in 0..100u64 {
            q.push(5, tag);
        }
        let tags: Vec<u64> = std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(tags, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn ties_break_by_seq_regardless_of_push_pattern() {
        // Interleave pushes of two timestamps in several patterns; within
        // each timestamp the pop order must always equal the push order.
        for pattern in 0..8u64 {
            let mut q: Scheduler<(Time, u64)> = Scheduler::new();
            let mut per_time: std::collections::BTreeMap<Time, Vec<u64>> = Default::default();
            for i in 0..50u64 {
                // deterministic pseudo-random interleaving of t=7 and t=3
                let t = if (i.wrapping_mul(pattern + 1) ^ i) % 3 == 0 { 7 } else { 3 };
                q.push(t, (t, i));
                per_time.entry(t).or_default().push(i);
            }
            let mut popped: std::collections::BTreeMap<Time, Vec<u64>> = Default::default();
            let mut last_t = 0;
            while let Some(e) = q.pop() {
                assert!(e.at >= last_t, "time went backwards");
                last_t = e.at;
                popped.entry(e.at).or_default().push(e.kind.1);
            }
            assert_eq!(popped, per_time, "pattern {pattern}");
        }
    }

    #[test]
    fn interleaved_push_pop_keeps_seq_monotone() {
        let mut q: Scheduler<u64> = Scheduler::new();
        q.push(5, 0);
        q.push(5, 1);
        assert_eq!(q.pop().unwrap().kind, 0);
        // pushes after a pop still order after the earlier survivors
        q.push(5, 2);
        q.push(5, 3);
        let rest: Vec<u64> = std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(rest, vec![1, 2, 3]);
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
    }

    // ------------------------------------------------------------------
    // Property tests: random event batches against a reference model
    // ------------------------------------------------------------------

    /// Random push batches, drained completely: pop times never decrease
    /// and ties pop FIFO per timestamp, for many seeds.
    #[test]
    fn prop_random_batches_preserve_time_order_and_fifo_ties() {
        for seed in 0..32u64 {
            let mut rng = Rng::new(seed ^ 0x5C4ED);
            let mut q: Scheduler<u64> = Scheduler::new();
            let mut pushed: BTreeMap<Time, Vec<u64>> = BTreeMap::new();
            let n = 1 + rng.index(200);
            for tag in 0..n as u64 {
                let t = rng.below(32) as Time;
                q.push(t, tag);
                pushed.entry(t).or_default().push(tag);
            }
            assert_eq!(q.len(), n);
            let mut popped: BTreeMap<Time, Vec<u64>> = BTreeMap::new();
            let mut last = 0;
            while let Some(e) = q.pop() {
                assert!(e.at >= last, "seed {seed}: time went backwards");
                last = e.at;
                popped.entry(e.at).or_default().push(e.kind);
            }
            assert_eq!(popped, pushed, "seed {seed}");
            assert!(q.is_empty());
        }
    }

    /// Random interleavings of push/pop against an exact reference model
    /// (a time-ordered map of FIFO queues): every pop must return the
    /// front of the earliest-time queue.
    #[test]
    fn prop_interleaved_ops_match_reference_model() {
        for seed in 0..32u64 {
            let mut rng = Rng::new(seed ^ 0x1F0);
            let mut q: Scheduler<u64> = Scheduler::new();
            let mut model: BTreeMap<Time, VecDeque<u64>> = BTreeMap::new();
            let mut tag = 0u64;
            for _ in 0..400 {
                if rng.chance(0.6) {
                    let t = rng.below(24) as Time;
                    q.push(t, tag);
                    model.entry(t).or_default().push_back(tag);
                    tag += 1;
                } else {
                    let want = model.iter_mut().next().map(|(&t, fifo)| {
                        let v = fifo.pop_front().unwrap();
                        (t, v)
                    });
                    if let Some((t, _)) = want {
                        if model[&t].is_empty() {
                            model.remove(&t);
                        }
                    }
                    let got = q.pop().map(|e| (e.at, e.kind));
                    assert_eq!(got, want, "seed {seed}");
                }
            }
            // drain what is left
            while let Some(e) = q.pop() {
                let (&t, fifo) = model.iter_mut().next().expect("model drained early");
                assert_eq!((e.at, e.kind), (t, fifo.pop_front().unwrap()));
                if fifo.is_empty() {
                    model.remove(&t);
                }
            }
            assert!(model.is_empty(), "seed {seed}: scheduler drained early");
        }
    }

    /// Random cancel interleavings: cancel-then-fire never panics, a
    /// cancelled event never pops, and double-cancel / cancel-after-pop
    /// report `false`.
    #[test]
    fn prop_cancel_then_fire_never_panics() {
        for seed in 0..32u64 {
            let mut rng = Rng::new(seed ^ 0xCA7CE1);
            let mut q: Scheduler<u64> = Scheduler::new();
            let mut model: BTreeMap<Time, VecDeque<(EventId, u64)>> = BTreeMap::new();
            let mut live: Vec<EventId> = Vec::new();
            let mut gone: Vec<EventId> = Vec::new();
            let mut tag = 0u64;
            for _ in 0..400 {
                let r = rng.next_f64();
                if r < 0.5 {
                    let t = rng.below(24) as Time;
                    let id = q.push(t, tag);
                    model.entry(t).or_default().push_back((id, tag));
                    live.push(id);
                    tag += 1;
                } else if r < 0.75 && !live.is_empty() {
                    let id = live.swap_remove(rng.index(live.len()));
                    assert!(q.cancel(id), "seed {seed}: live cancel failed");
                    for fifo in model.values_mut() {
                        fifo.retain(|&(i, _)| i != id);
                    }
                    model.retain(|_, fifo| !fifo.is_empty());
                    gone.push(id);
                } else if r < 0.85 && !gone.is_empty() {
                    // double-cancel / cancel-after-pop is a reported no-op
                    let id = gone[rng.index(gone.len())];
                    assert!(!q.cancel(id), "seed {seed}: dead cancel fired");
                } else {
                    let want = model.iter_mut().next().map(|(&t, fifo)| {
                        let (id, v) = fifo.pop_front().unwrap();
                        (t, id, v)
                    });
                    if let Some((t, _, _)) = want {
                        if model[&t].is_empty() {
                            model.remove(&t);
                        }
                    }
                    let got = q.pop().map(|e| (e.at, e.seq, e.kind));
                    assert_eq!(got, want, "seed {seed}");
                    if let Some((_, id, _)) = got {
                        live.retain(|&i| i != id);
                        gone.push(id);
                    }
                }
                // peek_time must always agree with the model's earliest
                assert_eq!(
                    q.peek_time(),
                    model.keys().next().copied(),
                    "seed {seed}"
                );
                assert_eq!(
                    q.len(),
                    model.values().map(|f| f.len()).sum::<usize>(),
                    "seed {seed}"
                );
            }
        }
    }

    #[test]
    fn push_at_seq_orders_by_external_counter() {
        let mut q: Scheduler<&'static str> = Scheduler::new();
        q.push_at_seq(10, 5, "b");
        q.push_at_seq(10, 9, "c");
        // a plain push continues after the external counter
        let id = q.push(10, "d");
        assert_eq!(id, 10);
        assert_eq!(q.len(), 3);
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(order, vec!["b", "c", "d"]);
        assert_eq!(q.next_seq(), 11);
    }

    #[test]
    fn peek_matches_next_pop_and_reaps_tombstones() {
        let mut q: Scheduler<u32> = Scheduler::new();
        let a = q.push(5, 1);
        q.push(7, 2);
        assert!(q.cancel(a));
        {
            let e = q.peek().expect("live event");
            assert_eq!((e.at, e.kind), (7, 2));
        }
        let e = q.pop().unwrap();
        assert_eq!((e.at, e.kind), (7, 2));
        assert!(q.peek().is_none());
    }

    #[test]
    fn bookkeeping_stays_bounded_by_live_span() {
        let mut q: Scheduler<u64> = Scheduler::new();
        for i in 0..100_000u64 {
            q.push(i as Time, i);
            if i % 5 == 0 {
                q.cancel(i); // keep the cancelled map exercised too
            }
            if i >= 8 {
                q.pop();
            }
        }
        // 100k events flowed through, but the live window only ever
        // holds a handful of seqs: the bitmaps must not grow with
        // history the way the old HashSets' capacity did.
        assert!(
            q.bookkeeping_bytes() <= 64,
            "bookkeeping grew to {} bytes",
            q.bookkeeping_bytes()
        );
    }

    #[test]
    fn cancel_skips_event_and_preserves_order() {
        let mut q: Scheduler<&'static str> = Scheduler::new();
        let _a = q.push(10, "a");
        let b = q.push(10, "b");
        let _c = q.push(20, "c");
        assert!(q.cancel(b));
        assert!(!q.cancel(b), "double cancel must be a no-op");
        assert_eq!(q.len(), 2);
        assert_eq!(q.peek_time(), Some(10));
        assert_eq!(q.pop().unwrap().kind, "a");
        assert_eq!(q.pop().unwrap().kind, "c");
        assert!(q.pop().is_none());
        // cancelling an already-popped id reports false, never panics
        assert!(!q.cancel(0));
        assert!(!q.cancel(999));
    }
}
