//! Path-based topology metrics (paper §II-B2,3): network diameter and
//! average shortest path length (ASPL), via all-sources BFS — O(N·E).

use crate::graph::traversal::bfs_distances;
use crate::graph::Graph;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PathMetrics {
    pub diameter: u32,
    pub avg_shortest_path: f64,
    pub connected: bool,
}

/// Compute diameter + ASPL over all ordered reachable pairs.
/// A disconnected graph reports `connected = false` and metrics over the
/// reachable pairs only (the harnesses treat that as a failed topology).
pub fn path_metrics(g: &Graph) -> PathMetrics {
    let n = g.n();
    if n <= 1 {
        return PathMetrics {
            diameter: 0,
            avg_shortest_path: 0.0,
            connected: true,
        };
    }
    let mut diameter = 0u32;
    let mut total = 0u64;
    let mut pairs = 0u64;
    let mut connected = true;
    for src in 0..n {
        let dist = bfs_distances(g, src);
        for (v, &d) in dist.iter().enumerate() {
            if v == src {
                continue;
            }
            if d == u32::MAX {
                connected = false;
                continue;
            }
            diameter = diameter.max(d);
            total += d as u64;
            pairs += 1;
        }
    }
    PathMetrics {
        diameter,
        avg_shortest_path: if pairs == 0 {
            f64::INFINITY
        } else {
            total as f64 / pairs as f64
        },
        connected,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_graph() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let m = path_metrics(&g);
        assert!(m.connected);
        assert_eq!(m.diameter, 3);
        // pairs (ordered): dists 1,2,3,1,1,2 doubled -> mean = 20/12
        assert!((m.avg_shortest_path - 20.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn complete_graph_diameter_one() {
        let mut g = Graph::new(5);
        for u in 0..5 {
            for v in (u + 1)..5 {
                g.add_edge(u, v);
            }
        }
        let m = path_metrics(&g);
        assert_eq!(m.diameter, 1);
        assert_eq!(m.avg_shortest_path, 1.0);
    }

    #[test]
    fn disconnected_flagged() {
        let g = Graph::from_edges(4, &[(0, 1), (2, 3)]);
        let m = path_metrics(&g);
        assert!(!m.connected);
    }

    #[test]
    fn ring_diameter() {
        let mut g = Graph::new(10);
        for i in 0..10 {
            g.add_edge(i, (i + 1) % 10);
        }
        assert_eq!(path_metrics(&g).diameter, 5);
    }
}
