//! Topology metric pipeline (paper §II-B): convergence factor (spectral),
//! diameter, and average shortest path length.

pub mod eigen;
pub mod paths;
pub mod spectral;

pub use paths::{path_metrics, PathMetrics};
pub use spectral::{convergence_factor, lambda, lambda_dense, MixingMatrix, DEFAULT_POWER_ITERS};

use crate::graph::Graph;

/// The three paper metrics for one topology, in one struct.
#[derive(Debug, Clone, Copy)]
pub struct TopologyMetrics {
    pub lambda: f64,
    pub convergence_factor: f64,
    pub diameter: u32,
    pub avg_shortest_path: f64,
    pub avg_degree: f64,
    pub max_degree: usize,
    pub connected: bool,
}

/// Evaluate all §II-B metrics on a graph.
pub fn evaluate(g: &Graph, seed: u64) -> TopologyMetrics {
    let l = lambda(g, DEFAULT_POWER_ITERS, seed);
    let p = path_metrics(g);
    TopologyMetrics {
        lambda: l,
        convergence_factor: if l >= 1.0 - 1e-12 {
            f64::INFINITY
        } else {
            1.0 / ((1.0 - l) * (1.0 - l))
        },
        diameter: p.diameter,
        avg_shortest_path: p.avg_shortest_path,
        avg_degree: g.avg_degree(),
        max_degree: g.max_degree(),
        connected: p.connected,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::random_regular;
    use crate::util::Rng;

    #[test]
    fn evaluate_reports_consistent_bundle() {
        let mut rng = Rng::new(8);
        let g = random_regular(50, 6, &mut rng);
        let m = evaluate(&g, 1);
        assert!(m.connected);
        assert!(m.lambda > 0.0 && m.lambda < 1.0);
        assert!(m.convergence_factor >= 1.0);
        assert!(m.diameter >= 2);
        assert!(m.avg_shortest_path > 1.0);
        assert!((m.avg_degree - 6.0).abs() < 1e-9);
        assert_eq!(m.max_degree, 6);
    }
}
