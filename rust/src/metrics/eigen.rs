//! Dense symmetric eigensolver (cyclic Jacobi rotations).
//!
//! Serves as the *oracle* for the fast matrix-free spectral-gap estimator
//! in `spectral.rs`: tests cross-check the power-iteration λ against the
//! full Jacobi spectrum on small graphs. Also usable directly for N up to
//! a few hundred (the paper's Fig. 3 uses N = 300).

/// Dense symmetric matrix in row-major storage.
#[derive(Debug, Clone)]
pub struct SymMatrix {
    pub n: usize,
    pub a: Vec<f64>,
}

impl SymMatrix {
    pub fn zeros(n: usize) -> Self {
        Self {
            n,
            a: vec![0.0; n * n],
        }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.n + j] = v;
        self.a[j * self.n + i] = v;
    }

    fn off_diag_norm(&self) -> f64 {
        let mut s = 0.0;
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                let x = self.get(i, j);
                s += 2.0 * x * x;
            }
        }
        s.sqrt()
    }
}

/// All eigenvalues of a symmetric matrix, sorted descending.
///
/// Cyclic Jacobi: O(n^3) per sweep, quadratic convergence; plenty for the
/// oracle role (n <= ~400 in tests and Fig. 3 harnesses).
pub fn eigenvalues_sym(m: &SymMatrix) -> Vec<f64> {
    let n = m.n;
    let mut a = m.clone();
    if n == 0 {
        return vec![];
    }
    if n == 1 {
        return vec![a.get(0, 0)];
    }
    let tol = 1e-12 * (1.0 + a.off_diag_norm());
    for _sweep in 0..100 {
        if a.off_diag_norm() < tol {
            break;
        }
        for p in 0..n - 1 {
            for q in (p + 1)..n {
                let apq = a.get(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a.get(p, p);
                let aqq = a.get(q, q);
                let theta = (aqq - app) / (2.0 * apq);
                // stable tangent of the rotation angle
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // apply the rotation G(p,q,theta) on both sides
                for k in 0..n {
                    let akp = a.get(k, p);
                    let akq = a.get(k, q);
                    a.set(k, p, c * akp - s * akq);
                    a.set(k, q, s * akp + c * akq);
                }
                // fix the 2x2 block analytically (numerically cleaner)
                let new_pp = app - t * apq;
                let new_qq = aqq + t * apq;
                a.a[p * n + p] = new_pp;
                a.a[q * n + q] = new_qq;
                a.set(p, q, 0.0);
            }
        }
    }
    let mut eig: Vec<f64> = (0..n).map(|i| a.get(i, i)).collect();
    eig.sort_by(|x, y| y.partial_cmp(x).unwrap());
    eig
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-8
    }

    #[test]
    fn diagonal_matrix() {
        let mut m = SymMatrix::zeros(3);
        m.set(0, 0, 3.0);
        m.set(1, 1, -1.0);
        m.set(2, 2, 2.0);
        let e = eigenvalues_sym(&m);
        assert!(close(e[0], 3.0) && close(e[1], 2.0) && close(e[2], -1.0));
    }

    #[test]
    fn two_by_two_known() {
        // [[2,1],[1,2]] -> eigenvalues 3, 1
        let mut m = SymMatrix::zeros(2);
        m.set(0, 0, 2.0);
        m.set(1, 1, 2.0);
        m.set(0, 1, 1.0);
        let e = eigenvalues_sym(&m);
        assert!(close(e[0], 3.0) && close(e[1], 1.0));
    }

    #[test]
    fn cycle_graph_adjacency_spectrum() {
        // adjacency eigenvalues of C_n are 2cos(2πk/n)
        let n = 8;
        let mut m = SymMatrix::zeros(n);
        for i in 0..n {
            m.set(i, (i + 1) % n, 1.0);
        }
        let mut want: Vec<f64> = (0..n)
            .map(|k| 2.0 * (2.0 * std::f64::consts::PI * k as f64 / n as f64).cos())
            .collect();
        want.sort_by(|x, y| y.partial_cmp(x).unwrap());
        let got = eigenvalues_sym(&m);
        for (g, w) in got.iter().zip(&want) {
            assert!(close(*g, *w), "{g} vs {w}");
        }
    }

    #[test]
    fn trace_preserved() {
        let mut m = SymMatrix::zeros(5);
        let mut rng = crate::util::Rng::new(9);
        for i in 0..5 {
            for j in i..5 {
                m.set(i, j, rng.gaussian());
            }
        }
        let trace: f64 = (0..5).map(|i| m.get(i, i)).sum();
        let sum: f64 = eigenvalues_sym(&m).iter().sum();
        assert!((trace - sum).abs() < 1e-8);
    }
}
