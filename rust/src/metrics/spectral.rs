//! Spectral topology metrics (paper §II-B1).
//!
//! The mixing matrix `M` of an overlay graph is its Metropolis–Hastings
//! matrix: `M_uv = 1/(1+max(d_u,d_v))` for edges, rows re-normalized onto
//! the diagonal. `M` is symmetric doubly-stochastic, so `λ₁ = 1` with the
//! uniform eigenvector; the paper's contraction constant is
//! `λ = max(|λ₂|, |λ_N|)` and the **convergence factor** is
//! `c_G = 1/(1-λ)²`.
//!
//! We compute λ matrix-free: λ is the spectral norm of the deflated
//! operator `B = M - 1·1ᵀ/N`, obtained by power iteration with the uniform
//! component projected out each step — O(iters · |E|), which handles the
//! paper's 1000-node scalability sweep in milliseconds. The dense Jacobi
//! solver (`eigen.rs`) is the test oracle.

use super::eigen::{eigenvalues_sym, SymMatrix};
use crate::graph::Graph;
use crate::util::Rng;

/// Metropolis–Hastings mixing weights as a sparse row representation.
#[derive(Debug, Clone)]
pub struct MixingMatrix {
    n: usize,
    /// (neighbor, weight) lists per node; diagonal stored separately.
    rows: Vec<Vec<(u32, f64)>>,
    diag: Vec<f64>,
}

impl MixingMatrix {
    /// Build the MH matrix of `g` (paper [5]: Boyd–Diaconis–Xiao).
    pub fn metropolis_hastings(g: &Graph) -> Self {
        let n = g.n();
        let mut rows = Vec::with_capacity(n);
        let mut diag = vec![0.0; n];
        for u in 0..n {
            let mut row = Vec::with_capacity(g.degree(u));
            let mut off = 0.0;
            for v in g.neighbors(u) {
                let w = 1.0 / (1.0 + g.degree(u).max(g.degree(v)) as f64);
                row.push((v as u32, w));
                off += w;
            }
            diag[u] = 1.0 - off;
            rows.push(row);
        }
        Self { n, rows, diag }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// y = M x
    pub fn mul(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.n);
        for u in 0..self.n {
            let mut acc = self.diag[u] * x[u];
            for &(v, w) in &self.rows[u] {
                acc += w * x[v as usize];
            }
            y[u] = acc;
        }
    }

    /// Dense copy (oracle / small-N paths).
    pub fn to_dense(&self) -> SymMatrix {
        let mut m = SymMatrix::zeros(self.n);
        for u in 0..self.n {
            m.set(u, u, self.diag[u]);
            for &(v, w) in &self.rows[u] {
                m.set(u, v as usize, w);
            }
        }
        m
    }

    /// Row-stochasticity check (used by tests and debug assertions).
    pub fn max_row_error(&self) -> f64 {
        (0..self.n)
            .map(|u| {
                let s: f64 = self.diag[u] + self.rows[u].iter().map(|&(_, w)| w).sum::<f64>();
                (s - 1.0).abs()
            })
            .fold(0.0, f64::max)
    }
}

fn project_out_uniform(x: &mut [f64]) {
    let mean = x.iter().sum::<f64>() / x.len() as f64;
    for v in x.iter_mut() {
        *v -= mean;
    }
}

fn norm(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// λ = max(|λ₂|, |λ_N|) via power iteration on the deflated operator.
///
/// Requires a connected graph (disconnected graphs have λ = 1 exactly; we
/// return 1.0 in that case by detecting stagnation at eigenvalue 1).
pub fn lambda(g: &Graph, iters: usize, seed: u64) -> f64 {
    let n = g.n();
    if n <= 1 {
        return 0.0;
    }
    let m = MixingMatrix::metropolis_hastings(g);
    let mut rng = Rng::new(seed ^ 0x5eed_1a3b);
    let mut x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    project_out_uniform(&mut x);
    let mut y = vec![0.0; n];
    let mut est = 0.0;
    for _ in 0..iters {
        let nx = norm(&x);
        if nx < 1e-300 {
            return 0.0; // x in the uniform space only: λ₂ ≈ 0
        }
        for v in x.iter_mut() {
            *v /= nx;
        }
        m.mul(&x, &mut y);
        project_out_uniform(&mut y);
        est = norm(&y);
        std::mem::swap(&mut x, &mut y);
    }
    est.min(1.0)
}

/// Convergence factor `c_G = 1/(1-λ)²` (paper §II-B1).
pub fn convergence_factor(g: &Graph, iters: usize, seed: u64) -> f64 {
    let l = lambda(g, iters, seed);
    if l >= 1.0 - 1e-12 {
        f64::INFINITY
    } else {
        1.0 / ((1.0 - l) * (1.0 - l))
    }
}

/// Oracle λ from the dense Jacobi spectrum (small N only).
pub fn lambda_dense(g: &Graph) -> f64 {
    let m = MixingMatrix::metropolis_hastings(g).to_dense();
    let eig = eigenvalues_sym(&m);
    if eig.len() < 2 {
        return 0.0;
    }
    // eig[0] == 1 (uniform); contraction is the next-largest magnitude.
    eig[1].abs().max(eig.last().unwrap().abs())
}

pub const DEFAULT_POWER_ITERS: usize = 300;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::random_regular;
    use crate::graph::Graph;

    fn ring(n: usize) -> Graph {
        let mut g = Graph::new(n);
        for i in 0..n {
            g.add_edge(i, (i + 1) % n);
        }
        g
    }

    fn complete(n: usize) -> Graph {
        let mut g = Graph::new(n);
        for u in 0..n {
            for v in (u + 1)..n {
                g.add_edge(u, v);
            }
        }
        g
    }

    #[test]
    fn mh_rows_are_stochastic() {
        let mut rng = Rng::new(3);
        let g = random_regular(60, 6, &mut rng);
        let m = MixingMatrix::metropolis_hastings(&g);
        assert!(m.max_row_error() < 1e-12);
    }

    #[test]
    fn power_matches_dense_oracle() {
        let mut rng = Rng::new(4);
        for &(n, d) in &[(20usize, 4usize), (40, 6), (60, 4)] {
            let g = random_regular(n, d, &mut rng);
            let fast = lambda(&g, 2_000, 11);
            let oracle = lambda_dense(&g);
            assert!(
                (fast - oracle).abs() < 1e-3,
                "n={n} d={d}: {fast} vs {oracle}"
            );
        }
    }

    #[test]
    fn ring_lambda_close_to_one() {
        // rings mix slowly: λ = (1 + 2cos(2π/n))/3 for MH on C_n -> ~1
        let g = ring(100);
        let l = lambda(&g, 3_000, 5);
        assert!(l > 0.99, "ring λ {l}");
    }

    #[test]
    fn complete_graph_mixes_fast() {
        let g = complete(20);
        let l = lambda(&g, 500, 5);
        assert!(l < 0.1, "complete λ {l}");
    }

    #[test]
    fn expander_beats_ring() {
        let mut rng = Rng::new(6);
        let rrg = random_regular(100, 8, &mut rng);
        let l_rrg = lambda(&rrg, 1_000, 5);
        let l_ring = lambda(&ring(100), 1_000, 5);
        assert!(l_rrg < l_ring - 0.1, "rrg {l_rrg} ring {l_ring}");
    }

    #[test]
    fn convergence_factor_monotone_in_lambda() {
        let mut rng = Rng::new(7);
        let good = random_regular(80, 10, &mut rng);
        let bad = ring(80);
        let cf_good = convergence_factor(&good, 1_000, 3);
        let cf_bad = convergence_factor(&bad, 1_000, 3);
        assert!(cf_good < cf_bad);
        assert!(cf_good >= 1.0);
    }
}
