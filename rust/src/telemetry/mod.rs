//! Lightweight metrics registry: named counters and gauges aggregated
//! across experiment components, plus table-friendly reporting.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// A process-wide metrics registry. Cheap counters; snapshot on demand.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut c = self.counters.lock().unwrap();
        c.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(by, Ordering::Relaxed);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|a| a.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    pub fn set_gauge(&self, name: &str, v: f64) {
        self.gauges.lock().unwrap().insert(name.to_string(), v);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    /// Render all metrics as sorted `name = value` lines.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k} = {}\n", v.load(Ordering::Relaxed)));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{k} = {v}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let r = Registry::new();
        r.incr("msgs", 3);
        r.incr("msgs", 2);
        r.set_gauge("accuracy", 0.9);
        assert_eq!(r.counter("msgs"), 5);
        assert_eq!(r.counter("absent"), 0);
        assert_eq!(r.gauge("accuracy"), Some(0.9));
        let text = r.render();
        assert!(text.contains("msgs = 5"));
        assert!(text.contains("accuracy = 0.9"));
    }
}
