//! MEP model aggregation (paper §III-C2):
//!
//!   ω_u = Σ_{j ∈ N ∪ {u}} c_j ω_j / Σ c_j
//!
//! Two interchangeable implementations:
//! * `aggregate_cpu` — pure Rust (used by large-scale simulations where
//!   the model vectors are small or synthetic);
//! * the AOT path — `runtime::Engine::aggregate` executes the L1 Pallas
//!   `weighted_agg` kernel inside the `<task>_agg` HLO artifact. The
//!   integration test `tests/runtime_integration.rs` pins the two
//!   implementations together.
//!
//! This module also owns the padding convention shared with L2:
//! `K_MAX` rows, zero weight ⇒ row ignored.
//!
//! # Byzantine-resilient aggregation
//!
//! A peer is not necessarily honest: one NaN/Inf model row (or weight)
//! fed to a plain weighted mean turns the whole aggregate non-finite
//! and the corruption spreads fleet-wide on the next exchange. Two
//! defenses live here:
//!
//! * every entry point skips rows carrying non-finite parameters or a
//!   non-finite weight — `aggregate_cpu_guarded` additionally reports
//!   how many rows were rejected so callers can surface the count as
//!   telemetry rather than averaging poison silently;
//! * [`Aggregation`] selects the combination rule: plain [`Mean`]
//!   (bitwise-identical to `aggregate_cpu`), coordinate-wise
//!   [`TrimmedMean`] and [`Median`], and [`Krum`] selection — the
//!   classic defenses against *finite* poison (scaled or sign-flipped
//!   models) that a NaN guard cannot catch.
//!
//! [`Mean`]: Aggregation::Mean
//! [`TrimmedMean`]: Aggregation::TrimmedMean
//! [`Median`]: Aggregation::Median
//! [`Krum`]: Aggregation::Krum

/// True when the row may participate in an aggregate: finite weight,
/// every parameter finite.
fn row_is_finite(model: &[f32], weight: f64) -> bool {
    weight.is_finite() && model.iter().all(|v| v.is_finite())
}

/// Aggregate models row-major `[k][p]` with weights `[k]` on the CPU.
///
/// Rows with a non-finite weight or any non-finite parameter are
/// skipped (never averaged). Use [`aggregate_cpu_guarded`] when the
/// caller needs the rejected-row count for telemetry.
pub fn aggregate_cpu(models: &[&[f32]], weights: &[f64]) -> Vec<f32> {
    aggregate_cpu_guarded(models, weights).0
}

/// [`aggregate_cpu`] plus the number of rows rejected as non-finite.
///
/// When *every* row is rejected the aggregate is the all-zero vector —
/// a documented sentinel (the caller should treat `rejected == k` as
/// "no usable models", exactly like an empty neighborhood).
pub fn aggregate_cpu_guarded(models: &[&[f32]], weights: &[f64]) -> (Vec<f32>, usize) {
    assert_eq!(models.len(), weights.len());
    assert!(!models.is_empty(), "aggregate of nothing");
    let p = models[0].len();
    assert!(models.iter().all(|m| m.len() == p), "ragged model stack");
    let mut rejected = 0usize;
    let mut denom = 0.0f64;
    let mut out = vec![0.0f64; p];
    for (m, &w) in models.iter().zip(weights) {
        if !row_is_finite(m, w) {
            rejected += 1;
            continue;
        }
        denom += w;
        if w == 0.0 {
            continue;
        }
        for (o, &x) in out.iter_mut().zip(m.iter()) {
            *o += w * x as f64;
        }
    }
    let denom = denom.max(1e-12);
    (out.into_iter().map(|x| (x / denom) as f32).collect(), rejected)
}

/// How a client combines its neighborhood's models: the paper's
/// confidence-weighted mean, or a Byzantine-robust rule.
///
/// `Mean` reduces bitwise to [`aggregate_cpu`]; the robust rules trade
/// some statistical efficiency for tolerance of poisoned rows.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Aggregation {
    /// Confidence-weighted mean (paper §III-C2) — the default.
    Mean,
    /// Coordinate-wise trimmed mean: drop the `⌊beta·k⌋` smallest and
    /// largest values per coordinate, weighted-average the rest.
    /// `beta ∈ (0, 0.5)`.
    TrimmedMean { beta: f64 },
    /// Coordinate-wise (unweighted) median.
    Median,
    /// Krum selection: keep the single model minimizing the summed
    /// squared distance to its `k − f − 2` nearest peers, assuming at
    /// most `f` Byzantine rows.
    Krum { f: usize },
}

impl Aggregation {
    /// Parse a CLI/TOML spelling: `mean`, `trimmed:<beta>`, `median`,
    /// `krum:<f>`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        if let Some(beta) = s.strip_prefix("trimmed:") {
            let beta: f64 = beta
                .parse()
                .map_err(|_| anyhow::anyhow!("trimmed:<beta> expects a number, got {beta:?}"))?;
            anyhow::ensure!(
                beta > 0.0 && beta < 0.5,
                "trimmed beta must be in (0, 0.5), got {beta}"
            );
            return Ok(Self::TrimmedMean { beta });
        }
        if let Some(f) = s.strip_prefix("krum:") {
            let f: usize = f
                .parse()
                .map_err(|_| anyhow::anyhow!("krum:<f> expects an integer, got {f:?}"))?;
            return Ok(Self::Krum { f });
        }
        match s {
            "mean" => Ok(Self::Mean),
            "median" => Ok(Self::Median),
            other => anyhow::bail!(
                "unknown aggregation {other:?} (expected mean|trimmed:<beta>|median|krum:<f>)"
            ),
        }
    }

    /// Short suffix for method names and reports.
    pub fn label(&self) -> String {
        match self {
            Self::Mean => "mean".into(),
            Self::TrimmedMean { beta } => format!("trimmed{}", (beta * 100.0).round() as u32),
            Self::Median => "median".into(),
            Self::Krum { f } => format!("krum{f}"),
        }
    }

    /// Apply the rule to finite rows. `Mean` is bitwise-identical to
    /// [`aggregate_cpu`]; the robust rules assume the caller already
    /// filtered non-finite rows (use [`apply_guarded`] otherwise).
    ///
    /// [`apply_guarded`]: Aggregation::apply_guarded
    pub fn apply(&self, models: &[&[f32]], weights: &[f64]) -> Vec<f32> {
        match *self {
            Self::Mean => aggregate_cpu(models, weights),
            Self::TrimmedMean { beta } => trimmed_mean_cpu(models, weights, beta),
            Self::Median => median_cpu(models),
            Self::Krum { f } => krum_cpu(models, f),
        }
    }

    /// Filter non-finite rows, then apply the rule to the survivors.
    /// Returns the aggregate plus the rejected-row count; all rows
    /// rejected ⇒ the all-zero vector (same sentinel as
    /// [`aggregate_cpu_guarded`]).
    pub fn apply_guarded(&self, models: &[&[f32]], weights: &[f64]) -> (Vec<f32>, usize) {
        assert_eq!(models.len(), weights.len());
        assert!(!models.is_empty(), "aggregate of nothing");
        if let Self::Mean = self {
            // single pass, bitwise-identical to aggregate_cpu
            return aggregate_cpu_guarded(models, weights);
        }
        let mut kept_m: Vec<&[f32]> = Vec::with_capacity(models.len());
        let mut kept_w: Vec<f64> = Vec::with_capacity(weights.len());
        for (m, &w) in models.iter().zip(weights) {
            if row_is_finite(m, w) {
                kept_m.push(m);
                kept_w.push(w);
            }
        }
        let rejected = models.len() - kept_m.len();
        if kept_m.is_empty() {
            return (vec![0.0f32; models[0].len()], rejected);
        }
        (self.apply(&kept_m, &kept_w), rejected)
    }
}

/// Coordinate-wise trimmed mean: per coordinate, sort the `k` values,
/// drop `⌊beta·k⌋` from each end (capped so at least one survives) and
/// take the weighted mean of the remainder.
pub fn trimmed_mean_cpu(models: &[&[f32]], weights: &[f64], beta: f64) -> Vec<f32> {
    assert_eq!(models.len(), weights.len());
    assert!(!models.is_empty(), "aggregate of nothing");
    let k = models.len();
    let p = models[0].len();
    assert!(models.iter().all(|m| m.len() == p), "ragged model stack");
    let trim = ((beta * k as f64).floor() as usize).min((k - 1) / 2);
    let mut col: Vec<(f32, f64)> = Vec::with_capacity(k);
    let mut out = vec![0.0f32; p];
    for (c, o) in out.iter_mut().enumerate() {
        col.clear();
        col.extend(models.iter().zip(weights).map(|(m, &w)| (m[c], w)));
        col.sort_by(|a, b| a.0.total_cmp(&b.0));
        let kept = &col[trim..k - trim];
        let denom: f64 = kept.iter().map(|&(_, w)| w).sum::<f64>().max(1e-12);
        let num: f64 = kept.iter().map(|&(v, w)| w * v as f64).sum();
        *o = (num / denom) as f32;
    }
    out
}

/// Coordinate-wise unweighted median (even counts average the two
/// central values).
pub fn median_cpu(models: &[&[f32]]) -> Vec<f32> {
    assert!(!models.is_empty(), "aggregate of nothing");
    let k = models.len();
    let p = models[0].len();
    assert!(models.iter().all(|m| m.len() == p), "ragged model stack");
    let mut col: Vec<f32> = Vec::with_capacity(k);
    let mut out = vec![0.0f32; p];
    for (c, o) in out.iter_mut().enumerate() {
        col.clear();
        col.extend(models.iter().map(|m| m[c]));
        col.sort_by(f32::total_cmp);
        *o = if k % 2 == 1 {
            col[k / 2]
        } else {
            ((col[k / 2 - 1] as f64 + col[k / 2] as f64) / 2.0) as f32
        };
    }
    out
}

/// Krum: score each row by the sum of its `k − f − 2` smallest squared
/// distances to the other rows (at least one), return the
/// lowest-scoring row (ties → lowest index, so selection is
/// deterministic).
pub fn krum_cpu(models: &[&[f32]], f: usize) -> Vec<f32> {
    assert!(!models.is_empty(), "aggregate of nothing");
    let k = models.len();
    let p = models[0].len();
    assert!(models.iter().all(|m| m.len() == p), "ragged model stack");
    if k == 1 {
        return models[0].to_vec();
    }
    let closest = k.saturating_sub(f + 2).max(1).min(k - 1);
    let mut best = 0usize;
    let mut best_score = f64::INFINITY;
    let mut dists: Vec<f64> = Vec::with_capacity(k - 1);
    for (i, mi) in models.iter().enumerate() {
        dists.clear();
        for (j, mj) in models.iter().enumerate() {
            if i == j {
                continue;
            }
            let d2: f64 = mi
                .iter()
                .zip(mj.iter())
                .map(|(a, b)| {
                    let d = *a as f64 - *b as f64;
                    d * d
                })
                .sum();
            dists.push(d2);
        }
        dists.sort_by(f64::total_cmp);
        let score: f64 = dists[..closest].iter().sum();
        if score < best_score {
            best_score = score;
            best = i;
        }
    }
    models[best].to_vec()
}

/// Pack a model stack into the fixed `[K_MAX, P]` buffer + `[K_MAX]`
/// weights the AOT `agg` artifact expects (extra rows zero-weighted).
pub fn pack_for_artifact(
    models: &[&[f32]],
    weights: &[f64],
    k_max: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert!(models.len() <= k_max, "{} models > K_MAX {k_max}", models.len());
    assert!(!models.is_empty());
    let p = models[0].len();
    let mut stack = vec![0.0f32; k_max * p];
    let mut w = vec![0.0f32; k_max];
    for (i, (m, &wi)) in models.iter().zip(weights).enumerate() {
        stack[i * p..(i + 1) * p].copy_from_slice(m);
        w[i] = wi as f32;
    }
    (stack, w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_model_identity() {
        let m = vec![1.0f32, -2.0, 3.5];
        let out = aggregate_cpu(&[&m], &[0.7]);
        for (a, b) in out.iter().zip(&m) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn equal_weights_is_mean() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 6.0];
        let out = aggregate_cpu(&[&a, &b], &[1.0, 1.0]);
        assert!((out[0] - 2.0).abs() < 1e-6);
        assert!((out[1] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn zero_weight_ignored() {
        let a = vec![1.0f32; 4];
        let junk = vec![1e30f32; 4];
        let out = aggregate_cpu(&[&a, &junk], &[1.0, 0.0]);
        assert!(out.iter().all(|&x| (x - 1.0).abs() < 1e-6));
    }

    #[test]
    fn weight_scale_invariant() {
        let a = vec![2.0f32, 0.0];
        let b = vec![0.0f32, 2.0];
        let x = aggregate_cpu(&[&a, &b], &[0.3, 0.7]);
        let y = aggregate_cpu(&[&a, &b], &[3.0, 7.0]);
        for (p, q) in x.iter().zip(&y) {
            assert!((p - q).abs() < 1e-6);
        }
    }

    #[test]
    fn nan_row_is_rejected_not_averaged() {
        // regression: one poisoned neighbor used to turn the whole
        // aggregate NaN and spread through every subsequent exchange
        let honest = vec![1.0f32, 2.0, 3.0];
        let poison = vec![f32::NAN; 3];
        let (out, rejected) = aggregate_cpu_guarded(&[&honest, &poison], &[1.0, 1.0]);
        assert_eq!(rejected, 1);
        assert!(out.iter().all(|v| v.is_finite()));
        for (a, b) in out.iter().zip(&honest) {
            assert!((a - b).abs() < 1e-6, "honest model should survive intact");
        }
    }

    #[test]
    fn inf_params_and_nan_weights_are_rejected() {
        let honest = vec![0.5f32, -0.5];
        let inf = vec![f32::INFINITY, 0.0];
        let fine = vec![1.5f32, -1.5];
        let (out, rejected) =
            aggregate_cpu_guarded(&[&honest, &inf, &fine], &[1.0, 1.0, f64::NAN]);
        assert_eq!(rejected, 2);
        assert_eq!(out, honest);
        // all rows poisoned: zero sentinel, everything counted
        let (out, rejected) = aggregate_cpu_guarded(&[&inf], &[1.0]);
        assert_eq!(rejected, 1);
        assert_eq!(out, vec![0.0, 0.0]);
    }

    #[test]
    fn mean_variant_is_bitwise_aggregate_cpu() {
        let a = vec![0.25f32, -1.5, 3.0];
        let b = vec![2.0f32, 0.125, -0.75];
        let c = vec![-1.0f32, 1.0, 0.5];
        let w = [0.3, 1.7, 0.9];
        let direct = aggregate_cpu(&[&a, &b, &c], &w);
        let via_enum = Aggregation::Mean.apply(&[&a, &b, &c], &w);
        assert_eq!(direct, via_enum, "Mean must reduce bitwise to aggregate_cpu");
        let (guarded, rejected) = Aggregation::Mean.apply_guarded(&[&a, &b, &c], &w);
        assert_eq!(direct, guarded);
        assert_eq!(rejected, 0);
    }

    #[test]
    fn trimmed_mean_drops_extremes() {
        let rows: Vec<Vec<f32>> = vec![
            vec![1.0, 10.0],
            vec![2.0, 20.0],
            vec![3.0, 30.0],
            vec![1000.0, -1000.0], // attacker
        ];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let out = trimmed_mean_cpu(&refs, &[1.0; 4], 0.25);
        // trim 1 from each end per coordinate: {2,3} and {10,20} survive
        assert!((out[0] - 2.5).abs() < 1e-6);
        assert!((out[1] - 15.0).abs() < 1e-6);
    }

    #[test]
    fn median_is_coordinate_wise() {
        let rows: Vec<Vec<f32>> = vec![
            vec![1.0, -5.0],
            vec![2.0, 0.0],
            vec![9.0, 5.0],
        ];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        assert_eq!(median_cpu(&refs), vec![2.0, 0.0]);
        // even count averages the central pair
        let rows2 = [vec![1.0f32], vec![3.0f32]];
        let refs2: Vec<&[f32]> = rows2.iter().map(|r| r.as_slice()).collect();
        assert_eq!(median_cpu(&refs2), vec![2.0]);
    }

    #[test]
    fn krum_picks_a_clustered_row() {
        let rows: Vec<Vec<f32>> = vec![
            vec![1.0, 1.0],
            vec![1.1, 0.9],
            vec![0.9, 1.1],
            vec![-50.0, 50.0], // attacker far from the cluster
        ];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let out = krum_cpu(&refs, 1);
        assert!(rows[..3].iter().any(|r| r.as_slice() == out.as_slice()));
    }

    #[test]
    fn aggregation_parse_and_labels_round_trip() {
        assert_eq!(Aggregation::parse("mean").unwrap(), Aggregation::Mean);
        assert_eq!(Aggregation::parse("median").unwrap(), Aggregation::Median);
        assert_eq!(
            Aggregation::parse("trimmed:0.2").unwrap(),
            Aggregation::TrimmedMean { beta: 0.2 }
        );
        assert_eq!(Aggregation::parse("krum:2").unwrap(), Aggregation::Krum { f: 2 });
        assert_eq!(Aggregation::TrimmedMean { beta: 0.2 }.label(), "trimmed20");
        assert_eq!(Aggregation::Krum { f: 2 }.label(), "krum2");
        assert!(Aggregation::parse("trimmed:0.6").is_err());
        assert!(Aggregation::parse("zork").is_err());
        assert!(Aggregation::parse("krum:x").is_err());
    }

    #[test]
    fn robust_rules_guard_non_finite_rows_too() {
        let honest = vec![1.0f32, 2.0];
        let poison = vec![f32::NAN, 1.0];
        for agg in [
            Aggregation::TrimmedMean { beta: 0.2 },
            Aggregation::Median,
            Aggregation::Krum { f: 1 },
        ] {
            let (out, rejected) = agg.apply_guarded(&[&honest, &poison], &[1.0, 1.0]);
            assert_eq!(rejected, 1, "{agg:?}");
            assert_eq!(out, honest, "{agg:?}");
        }
    }

    #[test]
    fn pack_layout_matches_artifact_abi() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 4.0];
        let (stack, w) = pack_for_artifact(&[&a, &b], &[0.5, 0.25], 4);
        assert_eq!(stack.len(), 8);
        assert_eq!(&stack[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&stack[4..], &[0.0; 4]);
        assert_eq!(w, vec![0.5, 0.25, 0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn pack_rejects_overflow() {
        let a = vec![0.0f32; 2];
        let ms: Vec<&[f32]> = vec![&a; 5];
        pack_for_artifact(&ms, &[1.0; 5], 4);
    }
}
