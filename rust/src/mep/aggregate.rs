//! MEP model aggregation (paper §III-C2):
//!
//!   ω_u = Σ_{j ∈ N ∪ {u}} c_j ω_j / Σ c_j
//!
//! Two interchangeable implementations:
//! * `aggregate_cpu` — pure Rust (used by large-scale simulations where
//!   the model vectors are small or synthetic);
//! * the AOT path — `runtime::Engine::aggregate` executes the L1 Pallas
//!   `weighted_agg` kernel inside the `<task>_agg` HLO artifact. The
//!   integration test `tests/runtime_integration.rs` pins the two
//!   implementations together.
//!
//! This module also owns the padding convention shared with L2:
//! `K_MAX` rows, zero weight ⇒ row ignored.

/// Aggregate models row-major `[k][p]` with weights `[k]` on the CPU.
pub fn aggregate_cpu(models: &[&[f32]], weights: &[f64]) -> Vec<f32> {
    assert_eq!(models.len(), weights.len());
    assert!(!models.is_empty(), "aggregate of nothing");
    let p = models[0].len();
    assert!(models.iter().all(|m| m.len() == p), "ragged model stack");
    let denom: f64 = weights.iter().sum::<f64>().max(1e-12);
    let mut out = vec![0.0f64; p];
    for (m, &w) in models.iter().zip(weights) {
        if w == 0.0 {
            continue;
        }
        for (o, &x) in out.iter_mut().zip(m.iter()) {
            *o += w * x as f64;
        }
    }
    out.into_iter().map(|x| (x / denom) as f32).collect()
}

/// Pack a model stack into the fixed `[K_MAX, P]` buffer + `[K_MAX]`
/// weights the AOT `agg` artifact expects (extra rows zero-weighted).
pub fn pack_for_artifact(
    models: &[&[f32]],
    weights: &[f64],
    k_max: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert!(models.len() <= k_max, "{} models > K_MAX {k_max}", models.len());
    assert!(!models.is_empty());
    let p = models[0].len();
    let mut stack = vec![0.0f32; k_max * p];
    let mut w = vec![0.0f32; k_max];
    for (i, (m, &wi)) in models.iter().zip(weights).enumerate() {
        stack[i * p..(i + 1) * p].copy_from_slice(m);
        w[i] = wi as f32;
    }
    (stack, w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_model_identity() {
        let m = vec![1.0f32, -2.0, 3.5];
        let out = aggregate_cpu(&[&m], &[0.7]);
        for (a, b) in out.iter().zip(&m) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn equal_weights_is_mean() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 6.0];
        let out = aggregate_cpu(&[&a, &b], &[1.0, 1.0]);
        assert!((out[0] - 2.0).abs() < 1e-6);
        assert!((out[1] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn zero_weight_ignored() {
        let a = vec![1.0f32; 4];
        let junk = vec![1e30f32; 4];
        let out = aggregate_cpu(&[&a, &junk], &[1.0, 0.0]);
        assert!(out.iter().all(|&x| (x - 1.0).abs() < 1e-6));
    }

    #[test]
    fn weight_scale_invariant() {
        let a = vec![2.0f32, 0.0];
        let b = vec![0.0f32, 2.0];
        let x = aggregate_cpu(&[&a, &b], &[0.3, 0.7]);
        let y = aggregate_cpu(&[&a, &b], &[3.0, 7.0]);
        for (p, q) in x.iter().zip(&y) {
            assert!((p - q).abs() < 1e-6);
        }
    }

    #[test]
    fn pack_layout_matches_artifact_abi() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 4.0];
        let (stack, w) = pack_for_artifact(&[&a, &b], &[0.5, 0.25], 4);
        assert_eq!(stack.len(), 8);
        assert_eq!(&stack[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&stack[4..], &[0.0; 4]);
        assert_eq!(w, vec![0.5, 0.25, 0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn pack_rejects_overflow() {
        let a = vec![0.0f32; 2];
        let ms: Vec<&[f32]> = vec![&a; 5];
        pack_for_artifact(&ms, &[1.0; 5], 4);
    }
}
