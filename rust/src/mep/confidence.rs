//! MEP confidence parameters (paper §III-C2).
//!
//! Each client self-evaluates its model quality along two axes:
//!
//! * **data divergence confidence** `c_d = 1 / exp(KL(D_loc || D_std))`
//!   where `D_loc` is the local label distribution and `D_std` the assumed
//!   iid (uniform) distribution;
//! * **communication confidence** `c_c = 1 / T_u` — clients that exchange
//!   more often carry fresher models.
//!
//! The overall confidence normalizes both against the *neighborhood*
//! maxima: `c = α_d · c_d/max(c_d) + α_c · c_c/max(c_c)`.

use crate::data::kl::kl_divergence_vs_uniform;

/// Data-divergence confidence from a local label histogram.
pub fn data_confidence(label_counts: &[u64]) -> f64 {
    let kl = kl_divergence_vs_uniform(label_counts);
    (-kl).exp()
}

/// Communication confidence from the exchange period (any time unit —
/// normalization cancels it).
///
/// A non-positive or non-finite period is a configuration error — the
/// `Config`/`TaskSpec` validators reject it before any exchange runs —
/// so this reports an error instead of panicking (the old `assert!`
/// was reachable from user TOML/CLI input).
pub fn comm_confidence(period: f64) -> anyhow::Result<f64> {
    anyhow::ensure!(
        period.is_finite() && period > 0.0,
        "exchange period must be positive and finite, got {period}"
    );
    Ok(1.0 / period)
}

/// Combined confidence of one client relative to its neighborhood
/// (paper: `max(c_d)`, `max(c_c)` over `u`'s neighbors ∪ {u}).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConfidenceParams {
    pub alpha_d: f64,
    pub alpha_c: f64,
}

impl Default for ConfidenceParams {
    fn default() -> Self {
        // paper: "the specific values of α_d and α_c can just be 0.5, 0.5"
        Self {
            alpha_d: 0.5,
            alpha_c: 0.5,
        }
    }
}

impl ConfidenceParams {
    /// Normalized confidence of client `u` within its neighborhood.
    ///
    /// `own` and `neighborhood` carry `(c_d, c_c)` raw values; the
    /// neighborhood slice must include the client itself.
    pub fn combine(&self, own: (f64, f64), neighborhood: &[(f64, f64)]) -> f64 {
        let max_d = neighborhood.iter().map(|p| p.0).fold(f64::MIN, f64::max);
        let max_c = neighborhood.iter().map(|p| p.1).fold(f64::MIN, f64::max);
        let nd = if max_d > 0.0 { own.0 / max_d } else { 0.0 };
        let nc = if max_c > 0.0 { own.1 / max_c } else { 0.0 };
        self.alpha_d * nd + self.alpha_c * nc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_data_has_max_confidence() {
        let c = data_confidence(&[10, 10, 10, 10]);
        assert!((c - 1.0).abs() < 1e-12);
    }

    #[test]
    fn skewed_data_lowers_confidence() {
        let balanced = data_confidence(&[10, 10, 10, 10]);
        let skewed = data_confidence(&[40, 0, 0, 0]);
        let mild = data_confidence(&[25, 15, 10, 10]);
        assert!(skewed < mild && mild < balanced);
        assert!(skewed > 0.0 && skewed <= 1.0);
    }

    #[test]
    fn comm_confidence_inverse() {
        assert!(comm_confidence(5.0).unwrap() > comm_confidence(10.0).unwrap());
        assert_eq!(comm_confidence(2.0).unwrap(), 0.5);
    }

    #[test]
    fn comm_confidence_rejects_degenerate_periods() {
        // previously an assert! panic, reachable from user config
        assert!(comm_confidence(0.0).is_err());
        assert!(comm_confidence(-1.0).is_err());
        assert!(comm_confidence(f64::NAN).is_err());
        assert!(comm_confidence(f64::INFINITY).is_err());
    }

    #[test]
    fn combine_normalizes_to_unit_interval() {
        let p = ConfidenceParams::default();
        let hood = [(1.0, 0.2), (0.5, 0.1), (0.8, 0.05)];
        for &own in &hood {
            let c = p.combine(own, &hood);
            assert!(c > 0.0 && c <= 1.0, "c={c}");
        }
        // the best-on-both-axes client gets exactly alpha_d + alpha_c
        let best = p.combine((1.0, 0.2), &hood);
        assert!((best - 1.0).abs() < 1e-12);
    }

    #[test]
    fn alphas_weight_the_axes() {
        let d_only = ConfidenceParams {
            alpha_d: 1.0,
            alpha_c: 0.0,
        };
        let hood = [(1.0, 0.01), (0.25, 1.0)];
        // client 0 has the best data, worst comm
        let c0 = d_only.combine(hood[0], &hood);
        let c1 = d_only.combine(hood[1], &hood);
        assert!(c0 > c1);
    }
}
