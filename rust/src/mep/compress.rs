//! Model-payload compression for MEP exchange (accuracy-vs-bytes
//! trade-off studies): symmetric per-tensor i8 quantization and top-k
//! magnitude sparsification.
//!
//! Both schemes are deterministic pure functions of the parameter
//! vector, so the sim and TCP backends compress identically and the
//! conformance suite can pin accuracy bitwise. The trainer applies the
//! *round-trip* (compress then decompress) to every model a client
//! pulls from a neighbor, so the learning dynamics see exactly the
//! parameters that would have survived the wire — while the byte
//! accounting charges the compressed size.

/// Symmetric per-tensor i8 quantization: `level = round(v / scale)`
/// with `scale = max |v| / 127`. Returns `(scale, levels)`;
/// an all-zero (or empty) tensor gets scale 0 and zero levels.
pub fn quantize_q8(params: &[f32]) -> (f32, Vec<i8>) {
    let max_abs = params.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if max_abs == 0.0 || !max_abs.is_finite() {
        return (0.0, vec![0; params.len()]);
    }
    let scale = max_abs / 127.0;
    let levels = params
        .iter()
        .map(|v| (v / scale).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (scale, levels)
}

/// Reconstruct a dense tensor from its quantization levels.
pub fn dequantize_q8(scale: f32, levels: &[i8]) -> Vec<f32> {
    levels.iter().map(|&l| l as f32 * scale).collect()
}

/// Keep the `k` largest-magnitude entries (ties broken toward the lower
/// index, so the selection is deterministic). Returns `(indices,
/// values)` with indices ascending; `k >= len` degenerates to the dense
/// tensor.
pub fn sparsify_topk(params: &[f32], k: usize) -> (Vec<u32>, Vec<f32>) {
    if k >= params.len() {
        return (
            (0..params.len() as u32).collect(),
            params.to_vec(),
        );
    }
    let mut order: Vec<u32> = (0..params.len() as u32).collect();
    // total order: magnitude descending, then index ascending — NaN
    // magnitudes sort last so they are only kept once everything finite
    // is in
    order.sort_by(|&a, &b| {
        let (ma, mb) = (params[a as usize].abs(), params[b as usize].abs());
        mb.partial_cmp(&ma)
            .unwrap_or_else(|| mb.is_nan().cmp(&ma.is_nan()))
            .then(a.cmp(&b))
    });
    let mut indices: Vec<u32> = order[..k].to_vec();
    indices.sort_unstable();
    let values = indices.iter().map(|&i| params[i as usize]).collect();
    (indices, values)
}

/// Reconstruct the dense `dim`-vector from a top-k selection: kept
/// entries land at their index, everything else is zero. Out-of-range
/// indices (a corrupt frame) are ignored rather than panicking.
pub fn densify_topk(dim: usize, indices: &[u32], values: &[f32]) -> Vec<f32> {
    let mut dense = vec![0.0f32; dim];
    for (&i, &v) in indices.iter().zip(values.iter()) {
        if let Some(slot) = dense.get_mut(i as usize) {
            *slot = v;
        }
    }
    dense
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q8_roundtrip_error_is_bounded_by_half_step() {
        let params: Vec<f32> = (0..256).map(|i| (i as f32 - 128.0) * 0.013).collect();
        let (scale, levels) = quantize_q8(&params);
        let back = dequantize_q8(scale, &levels);
        assert_eq!(back.len(), params.len());
        for (p, b) in params.iter().zip(back.iter()) {
            assert!(
                (p - b).abs() <= scale * 0.5 + f32::EPSILON,
                "{p} -> {b} off by more than half a step ({scale})"
            );
        }
    }

    #[test]
    fn q8_is_deterministic_and_handles_degenerate_tensors() {
        let params = vec![0.5, -1.0, 0.25];
        assert_eq!(quantize_q8(&params), quantize_q8(&params));
        // extremes map to the extreme levels
        let (_, levels) = quantize_q8(&params);
        assert_eq!(levels[1], -127);
        // all-zero and empty tensors: scale 0, zero levels, no NaNs
        assert_eq!(quantize_q8(&[0.0, 0.0]), (0.0, vec![0, 0]));
        assert_eq!(quantize_q8(&[]), (0.0, vec![]));
        assert_eq!(dequantize_q8(0.0, &[0, 0]), vec![0.0, 0.0]);
    }

    #[test]
    fn topk_selects_largest_magnitudes_with_stable_ties() {
        let params = vec![0.1, -3.0, 0.2, 3.0, -0.2, 2.0];
        let (indices, values) = sparsify_topk(&params, 3);
        // |−3.0| and |3.0| tie: the lower index (1) wins first, both fit
        assert_eq!(indices, vec![1, 3, 5]);
        assert_eq!(values, vec![-3.0, 3.0, 2.0]);
        // tie at the cut: k=1 keeps index 1, not 3
        let (indices, _) = sparsify_topk(&params, 1);
        assert_eq!(indices, vec![1]);
        // k >= len degenerates to dense
        let (indices, values) = sparsify_topk(&params, 99);
        assert_eq!(indices.len(), params.len());
        assert_eq!(values, params);
    }

    #[test]
    fn topk_densify_roundtrip_zeroes_the_rest() {
        let params = vec![1.0, 0.0, -2.0, 0.5, 0.0, 4.0];
        let (indices, values) = sparsify_topk(&params, 2);
        let dense = densify_topk(params.len(), &indices, &values);
        assert_eq!(dense, vec![0.0, 0.0, -2.0, 0.0, 0.0, 4.0]);
        // corrupt out-of-range index: ignored, no panic
        let dense = densify_topk(3, &[0, 9], &[1.0, 2.0]);
        assert_eq!(dense, vec![1.0, 0.0, 0.0]);
    }
}
