//! Model-payload compression for MEP exchange (accuracy-vs-bytes
//! trade-off studies): symmetric per-tensor i8 quantization and top-k
//! magnitude sparsification.
//!
//! Both schemes are deterministic pure functions of the parameter
//! vector, so the sim and TCP backends compress identically and the
//! conformance suite can pin accuracy bitwise. The trainer applies the
//! *round-trip* (compress then decompress) to every model a client
//! pulls from a neighbor, so the learning dynamics see exactly the
//! parameters that would have survived the wire — while the byte
//! accounting charges the compressed size.

/// Symmetric per-tensor i8 quantization: `level = round(v / scale)`
/// with `scale = max |v| / 127`. Returns `(scale, levels)`;
/// an all-zero (or empty) tensor gets scale 0 and zero levels.
///
/// Non-finite entries (NaN/±Inf — a poisoned payload) are *sanitized*:
/// they quantize to level 0 and are excluded from the scale
/// computation, so one corrupt parameter can neither smuggle NaN
/// through the wire nor zero the entire tensor. `-0.0` behaves as 0.
pub fn quantize_q8(params: &[f32]) -> (f32, Vec<i8>) {
    let max_abs = params
        .iter()
        .filter(|v| v.is_finite())
        .fold(0.0f32, |m, v| m.max(v.abs()));
    if max_abs == 0.0 {
        return (0.0, vec![0; params.len()]);
    }
    let scale = max_abs / 127.0;
    let levels = params
        .iter()
        .map(|v| {
            if v.is_finite() {
                (v / scale).round().clamp(-127.0, 127.0) as i8
            } else {
                0
            }
        })
        .collect();
    (scale, levels)
}

/// Reconstruct a dense tensor from its quantization levels.
pub fn dequantize_q8(scale: f32, levels: &[i8]) -> Vec<f32> {
    levels.iter().map(|&l| l as f32 * scale).collect()
}

/// Keep the `k` largest-magnitude entries (ties broken toward the lower
/// index, so the selection is deterministic). Returns `(indices,
/// values)` with indices ascending; `k >= len` degenerates to the dense
/// tensor.
///
/// Non-finite entries are *sanitized* to 0.0 — they rank as magnitude
/// zero and emit 0.0 when selected, matching [`quantize_q8`]'s handling
/// of the same corrupt input: no wire scheme forwards NaN/Inf.
pub fn sparsify_topk(params: &[f32], k: usize) -> (Vec<u32>, Vec<f32>) {
    let sane = |v: f32| if v.is_finite() { v } else { 0.0 };
    if k >= params.len() {
        return (
            (0..params.len() as u32).collect(),
            params.iter().map(|&v| sane(v)).collect(),
        );
    }
    let mut order: Vec<u32> = (0..params.len() as u32).collect();
    // total order: sanitized magnitude descending, then index ascending
    order.sort_by(|&a, &b| {
        let (ma, mb) = (sane(params[a as usize]).abs(), sane(params[b as usize]).abs());
        mb.total_cmp(&ma).then(a.cmp(&b))
    });
    let mut indices: Vec<u32> = order[..k].to_vec();
    indices.sort_unstable();
    let values = indices.iter().map(|&i| sane(params[i as usize])).collect();
    (indices, values)
}

/// Reconstruct the dense `dim`-vector from a top-k selection: kept
/// entries land at their index, everything else is zero. Out-of-range
/// indices (a corrupt frame) are ignored rather than panicking.
pub fn densify_topk(dim: usize, indices: &[u32], values: &[f32]) -> Vec<f32> {
    let mut dense = vec![0.0f32; dim];
    for (&i, &v) in indices.iter().zip(values.iter()) {
        if let Some(slot) = dense.get_mut(i as usize) {
            *slot = v;
        }
    }
    dense
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q8_roundtrip_error_is_bounded_by_half_step() {
        let params: Vec<f32> = (0..256).map(|i| (i as f32 - 128.0) * 0.013).collect();
        let (scale, levels) = quantize_q8(&params);
        let back = dequantize_q8(scale, &levels);
        assert_eq!(back.len(), params.len());
        for (p, b) in params.iter().zip(back.iter()) {
            assert!(
                (p - b).abs() <= scale * 0.5 + f32::EPSILON,
                "{p} -> {b} off by more than half a step ({scale})"
            );
        }
    }

    #[test]
    fn q8_is_deterministic_and_handles_degenerate_tensors() {
        let params = vec![0.5, -1.0, 0.25];
        assert_eq!(quantize_q8(&params), quantize_q8(&params));
        // extremes map to the extreme levels
        let (_, levels) = quantize_q8(&params);
        assert_eq!(levels[1], -127);
        // all-zero and empty tensors: scale 0, zero levels, no NaNs
        assert_eq!(quantize_q8(&[0.0, 0.0]), (0.0, vec![0, 0]));
        assert_eq!(quantize_q8(&[]), (0.0, vec![]));
        assert_eq!(dequantize_q8(0.0, &[0, 0]), vec![0.0, 0.0]);
    }

    #[test]
    fn topk_selects_largest_magnitudes_with_stable_ties() {
        let params = vec![0.1, -3.0, 0.2, 3.0, -0.2, 2.0];
        let (indices, values) = sparsify_topk(&params, 3);
        // |−3.0| and |3.0| tie: the lower index (1) wins first, both fit
        assert_eq!(indices, vec![1, 3, 5]);
        assert_eq!(values, vec![-3.0, 3.0, 2.0]);
        // tie at the cut: k=1 keeps index 1, not 3
        let (indices, _) = sparsify_topk(&params, 1);
        assert_eq!(indices, vec![1]);
        // k >= len degenerates to dense
        let (indices, values) = sparsify_topk(&params, 99);
        assert_eq!(indices.len(), params.len());
        assert_eq!(values, params);
    }

    #[test]
    fn q8_sanitizes_non_finite_without_zeroing_the_tensor() {
        // NaN and Inf entries quantize to level 0; finite entries keep
        // their scale (the old behavior zeroed the whole tensor on Inf)
        let params = vec![f32::NAN, 1.0, f32::INFINITY, -2.0, f32::NEG_INFINITY];
        let (scale, levels) = quantize_q8(&params);
        assert!((scale - 2.0 / 127.0).abs() < 1e-9);
        assert_eq!(levels[0], 0);
        assert_eq!(levels[2], 0);
        assert_eq!(levels[4], 0);
        assert_eq!(levels[3], -127);
        let back = dequantize_q8(scale, &levels);
        assert!(back.iter().all(|v| v.is_finite()));
        assert!((back[1] - 1.0).abs() <= scale * 0.5 + f32::EPSILON);
        // an all-non-finite tensor degenerates like all-zero
        assert_eq!(quantize_q8(&[f32::NAN, f32::INFINITY]), (0.0, vec![0, 0]));
        // -0.0 behaves as zero on both sides of the round trip
        let (scale, levels) = quantize_q8(&[-0.0, 1.0]);
        assert_eq!(levels[0], 0);
        assert_eq!(dequantize_q8(scale, &levels)[0], 0.0);
    }

    #[test]
    fn topk_sanitizes_non_finite_and_never_prefers_them() {
        let params = vec![f32::NAN, 0.5, f32::INFINITY, 2.0, -1.0];
        let (indices, values) = sparsify_topk(&params, 3);
        // non-finite entries rank as magnitude 0: the three finite
        // entries win, in index order
        assert_eq!(indices, vec![1, 3, 4]);
        assert_eq!(values, vec![0.5, 2.0, -1.0]);
        // even when forced in (k >= finite count), they emit 0.0
        let (_, values) = sparsify_topk(&params, 5);
        assert!(values.iter().all(|v| v.is_finite()));
        assert_eq!(values, vec![0.0, 0.5, 0.0, 2.0, -1.0]);
        // -0.0 survives as a zero-magnitude finite value
        let (indices, values) = sparsify_topk(&[-0.0, 3.0], 1);
        assert_eq!(indices, vec![1]);
        assert_eq!(values, vec![3.0]);
        let dense = densify_topk(2, &indices, &values);
        assert_eq!(dense, vec![0.0, 3.0]);
    }

    #[test]
    fn topk_densify_roundtrip_zeroes_the_rest() {
        let params = vec![1.0, 0.0, -2.0, 0.5, 0.0, 4.0];
        let (indices, values) = sparsify_topk(&params, 2);
        let dense = densify_topk(params.len(), &indices, &values);
        assert_eq!(dense, vec![0.0, 0.0, -2.0, 0.0, 0.0, 4.0]);
        // corrupt out-of-range index: ignored, no panic
        let dense = densify_topk(3, &[0, 9], &[1.0, 2.0]);
        assert_eq!(dense, vec![1.0, 0.0, 0.0]);
    }
}
