//! Model fingerprinting for de-duplication (paper §III-C3): before sending
//! a model, a client offers its fingerprint; the receiver skips the
//! transfer when the fingerprint matches the copy it already holds.

use sha2::{Digest, Sha256};

/// 64-bit fingerprint of a flat parameter vector (truncated SHA-256 of the
//  raw little-endian f32 bytes — "a public hash function" per the paper).
pub fn fingerprint(params: &[f32]) -> u64 {
    let mut h = Sha256::new();
    // §Perf iteration 2: fixed stack buffer instead of a Vec per chunk
    // (~1.7× on 100k-param models).
    let mut buf = [0u8; 4096 * 4];
    for chunk in params.chunks(4096) {
        for (i, f) in chunk.iter().enumerate() {
            buf[i * 4..i * 4 + 4].copy_from_slice(&f.to_le_bytes());
        }
        h.update(&buf[..chunk.len() * 4]);
    }
    let d = h.finalize();
    u64::from_le_bytes(d[..8].try_into().unwrap())
}

/// Per-neighbor fingerprint cache deciding whether a transfer is needed.
#[derive(Debug, Clone, Default)]
pub struct FingerprintCache {
    entries: std::collections::BTreeMap<u64, u64>, // neighbor -> fp
}

impl FingerprintCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the fingerprint of the model we last received from (or sent
    /// to) `neighbor`.
    pub fn record(&mut self, neighbor: u64, fp: u64) {
        self.entries.insert(neighbor, fp);
    }

    /// Would sending a model with fingerprint `fp` to `neighbor` be a
    /// duplicate of what they already have?
    pub fn is_duplicate(&self, neighbor: u64, fp: u64) -> bool {
        self.entries.get(&neighbor) == Some(&fp)
    }

    pub fn forget(&mut self, neighbor: u64) {
        self.entries.remove(&neighbor);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_deterministic_and_sensitive() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![1.0f32, 2.0, 3.0];
        let c = vec![1.0f32, 2.0, 3.001];
        assert_eq!(fingerprint(&a), fingerprint(&b));
        assert_ne!(fingerprint(&a), fingerprint(&c));
        assert_ne!(fingerprint(&a), fingerprint(&a[..2].to_vec()));
    }

    #[test]
    fn cache_dedup_flow() {
        let mut cache = FingerprintCache::new();
        let model = vec![0.5f32; 100];
        let fp = fingerprint(&model);
        assert!(!cache.is_duplicate(7, fp));
        cache.record(7, fp);
        assert!(cache.is_duplicate(7, fp));
        // model changed -> transfer needed again
        let fp2 = fingerprint(&vec![0.6f32; 100]);
        assert!(!cache.is_duplicate(7, fp2));
        cache.forget(7);
        assert!(!cache.is_duplicate(7, fp));
    }
}
