//! Model fingerprinting for de-duplication (paper §III-C3): before sending
//! a model, a client offers its fingerprint; the receiver skips the
//! transfer when the fingerprint matches the copy it already holds.
//!
//! With the multi-task engine several independent models ride the same
//! overlay, so cache entries are keyed by `(neighbor, task)`: one task's
//! duplicate suppression can never eat another task's model, and peer
//! expiry can be targeted per task (`forget_task`) instead of dropping a
//! whole neighbor's dedup state.

use sha2::{Digest, Sha256};

/// 64-bit fingerprint of a flat parameter vector (truncated SHA-256 of the
//  raw little-endian f32 bytes — "a public hash function" per the paper).
pub fn fingerprint(params: &[f32]) -> u64 {
    let mut h = Sha256::new();
    // §Perf iteration 2: fixed stack buffer instead of a Vec per chunk
    // (~1.7× on 100k-param models).
    let mut buf = [0u8; 4096 * 4];
    for chunk in params.chunks(4096) {
        for (i, f) in chunk.iter().enumerate() {
            buf[i * 4..i * 4 + 4].copy_from_slice(&f.to_le_bytes());
        }
        h.update(&buf[..chunk.len() * 4]);
    }
    let d = h.finalize();
    u64::from_le_bytes(d[..8].try_into().unwrap())
}

/// Per-`(neighbor, task)` fingerprint cache deciding whether a transfer is
/// needed. Single-task callers pass task `0` everywhere.
///
/// Placement note: today's holders are already task-scoped (the trainer
/// keeps one cache per client per lane, the TCP node trains one task),
/// so each instance usually holds a single task key — the keying makes
/// the no-cross-task-suppression invariant *structural* rather than an
/// accident of placement, and is what a node hosting several tasks over
/// one peer connection (the wire frames already carry `task`) keys by.
#[derive(Debug, Clone, Default)]
pub struct FingerprintCache {
    entries: std::collections::BTreeMap<(u64, u32), u64>, // (neighbor, task) -> fp
}

impl FingerprintCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the fingerprint of the `task` model we last received from
    /// (or sent to) `neighbor`.
    pub fn record(&mut self, neighbor: u64, task: u32, fp: u64) {
        self.entries.insert((neighbor, task), fp);
    }

    /// Would sending a `task` model with fingerprint `fp` to `neighbor`
    /// be a duplicate of what they already have?
    pub fn is_duplicate(&self, neighbor: u64, task: u32, fp: u64) -> bool {
        self.entries.get(&(neighbor, task)) == Some(&fp)
    }

    /// Drop every task's entry for `neighbor` — the peer left the overlay
    /// entirely (failure detection, graceful leave).
    pub fn forget(&mut self, neighbor: u64) {
        let keys: Vec<(u64, u32)> = self
            .entries
            .range((neighbor, 0)..=(neighbor, u32::MAX))
            .map(|(&k, _)| k)
            .collect();
        for k in keys {
            self.entries.remove(&k);
        }
    }

    /// Targeted expiry: drop only `(neighbor, task)`. One task's peer
    /// state expiring must not evict another task's dedup entries.
    pub fn forget_task(&mut self, neighbor: u64, task: u32) {
        self.entries.remove(&(neighbor, task));
    }

    /// Number of cached `(neighbor, task)` entries (telemetry).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_deterministic_and_sensitive() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![1.0f32, 2.0, 3.0];
        let c = vec![1.0f32, 2.0, 3.001];
        assert_eq!(fingerprint(&a), fingerprint(&b));
        assert_ne!(fingerprint(&a), fingerprint(&c));
        assert_ne!(fingerprint(&a), fingerprint(&a[..2].to_vec()));
    }

    #[test]
    fn cache_dedup_flow() {
        let mut cache = FingerprintCache::new();
        let model = vec![0.5f32; 100];
        let fp = fingerprint(&model);
        assert!(!cache.is_duplicate(7, 0, fp));
        cache.record(7, 0, fp);
        assert!(cache.is_duplicate(7, 0, fp));
        // model changed -> transfer needed again
        let fp2 = fingerprint(&vec![0.6f32; 100]);
        assert!(!cache.is_duplicate(7, 0, fp2));
        cache.forget(7);
        assert!(!cache.is_duplicate(7, 0, fp));
    }

    #[test]
    fn tasks_are_isolated_namespaces() {
        let mut cache = FingerprintCache::new();
        let fp = fingerprint(&[1.0f32, 2.0]);
        cache.record(3, 0, fp);
        // the same fingerprint for another task is NOT a duplicate:
        // suppression never crosses tasks
        assert!(cache.is_duplicate(3, 0, fp));
        assert!(!cache.is_duplicate(3, 1, fp));
        cache.record(3, 1, fp);
        assert!(cache.is_duplicate(3, 1, fp));
    }

    /// Regression: expiring one task's peer state must not evict another
    /// task's dedup entries — `forget_task` is targeted, while `forget`
    /// (whole-peer expiry) still clears every task of that neighbor and
    /// nothing of any other neighbor.
    #[test]
    fn targeted_forget_keeps_other_tasks_and_neighbors() {
        let mut cache = FingerprintCache::new();
        let fp_a = fingerprint(&[1.0f32]);
        let fp_b = fingerprint(&[2.0f32]);
        cache.record(7, 0, fp_a);
        cache.record(7, 1, fp_b);
        cache.record(8, 0, fp_a);
        assert_eq!(cache.len(), 3);

        cache.forget_task(7, 0);
        assert!(!cache.is_duplicate(7, 0, fp_a), "task 0 entry must expire");
        assert!(
            cache.is_duplicate(7, 1, fp_b),
            "task 1 entry must survive task 0 expiry"
        );
        assert!(cache.is_duplicate(8, 0, fp_a), "other neighbors untouched");

        // whole-peer expiry clears every task of neighbor 7 only
        cache.record(7, 0, fp_a);
        cache.forget(7);
        assert!(!cache.is_duplicate(7, 0, fp_a));
        assert!(!cache.is_duplicate(7, 1, fp_b));
        assert!(cache.is_duplicate(8, 0, fp_a));
        assert_eq!(cache.len(), 1);
    }
}
