//! Asynchronous exchange scheduling (paper §III-C1).
//!
//! Each client has its own communication period `T_u` derived from its
//! capacity tier (coarse-grained setting) or a measured minimum times a
//! safety factor η (fine-grained). Two neighbors exchange at
//! `max(T_u, T_v)`, so one client can run different periods per neighbor.

use crate::ndmp::messages::Time;

/// Client capacity tiers (paper §IV-A2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Capacity {
    High,
    Medium,
    Low,
}

impl Capacity {
    /// Time scale factor relative to a medium-capacity client
    /// (high = 2/3×, low = 2×; paper §IV-A2).
    pub fn scale(self) -> f64 {
        match self {
            Capacity::High => 2.0 / 3.0,
            Capacity::Medium => 1.0,
            Capacity::Low => 2.0,
        }
    }

    /// Deterministic tier assignment with the paper's 60/20/20 split.
    pub fn assign(index: usize, total: usize) -> Capacity {
        // interleave deterministically: every 5th is high, every 5th+1 low
        let _ = total;
        match index % 5 {
            0 => Capacity::High,
            1 => Capacity::Low,
            _ => Capacity::Medium,
        }
    }
}

/// Per-client schedule state.
#[derive(Debug, Clone)]
pub struct ExchangeSchedule {
    /// Own communication period `T_u` (µs).
    pub period: Time,
    /// Synchronous mode runs everyone at the max period instead.
    pub synchronous: bool,
}

impl ExchangeSchedule {
    /// Coarse-grained: base period scaled by capacity tier.
    pub fn coarse(base_period: Time, cap: Capacity) -> Self {
        Self {
            period: (base_period as f64 * cap.scale()) as Time,
            synchronous: false,
        }
    }

    /// Fine-grained: measured minimum duration × η (η > 1).
    pub fn fine(t_min: Time, eta: f64) -> Self {
        assert!(eta > 1.0, "η must exceed 1");
        Self {
            period: (t_min as f64 * eta) as Time,
            synchronous: false,
        }
    }

    /// The pairwise exchange period with a neighbor of period `other`
    /// (paper: `max(T_u, T_v)`).
    pub fn pair_period(&self, other: Time) -> Time {
        self.period.max(other)
    }

    /// Next exchange deadline for a neighbor given the last exchange time.
    pub fn next_exchange(&self, last: Time, neighbor_period: Time) -> Time {
        last + self.pair_period(neighbor_period)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_scales() {
        assert!(Capacity::High.scale() < Capacity::Medium.scale());
        assert!(Capacity::Low.scale() > Capacity::Medium.scale());
    }

    #[test]
    fn assignment_matches_paper_split() {
        let n = 100;
        let mut counts = [0usize; 3];
        for i in 0..n {
            match Capacity::assign(i, n) {
                Capacity::High => counts[0] += 1,
                Capacity::Low => counts[1] += 1,
                Capacity::Medium => counts[2] += 1,
            }
        }
        assert_eq!(counts, [20, 20, 60]); // 20% high, 20% low, 60% medium
    }

    #[test]
    fn pair_period_is_max() {
        let s = ExchangeSchedule::coarse(10_000, Capacity::High); // ~6667
        assert_eq!(s.pair_period(20_000), 20_000);
        assert_eq!(s.pair_period(1_000), s.period);
    }

    #[test]
    fn fine_grained_applies_eta() {
        let s = ExchangeSchedule::fine(9_000, 1.5);
        assert_eq!(s.period, 13_500);
    }

    #[test]
    #[should_panic]
    fn fine_grained_rejects_eta_below_one() {
        ExchangeSchedule::fine(1_000, 0.9);
    }

    #[test]
    fn next_exchange_advances() {
        let s = ExchangeSchedule::coarse(5_000, Capacity::Medium);
        assert_eq!(s.next_exchange(100, 5_000), 5_100);
        assert_eq!(s.next_exchange(100, 8_000), 8_100);
    }
}
