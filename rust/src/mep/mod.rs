//! Model Exchange Protocol (paper §III-C): asynchronous per-client
//! exchange periods, confidence-weighted aggregation, and fingerprint
//! de-duplication.

pub mod aggregate;
pub mod compress;
pub mod confidence;
pub mod fingerprint;
pub mod schedule;

pub use aggregate::{
    aggregate_cpu, aggregate_cpu_guarded, krum_cpu, median_cpu, pack_for_artifact,
    trimmed_mean_cpu, Aggregation,
};
pub use compress::{dequantize_q8, densify_topk, quantize_q8, sparsify_topk};
pub use confidence::{comm_confidence, data_confidence, ConfidenceParams};
pub use fingerprint::{fingerprint, FingerprintCache};
pub use schedule::{Capacity, ExchangeSchedule};
