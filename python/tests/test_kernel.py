"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/weight regimes and asserts allclose against
``compile.kernels.ref`` — the core correctness signal for the AOT stack.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import EPS, sgd_step_ref, weighted_agg_ref
from compile.kernels.sgd_step import sgd_step
from compile.kernels.weighted_agg import weighted_agg

RTOL = 1e-5
ATOL = 1e-6


def rand(rs, *shape, dtype=np.float32):
    return jnp.asarray(rs.standard_normal(shape).astype(dtype))


# ---------------------------------------------------------------------------
# weighted_agg
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(min_value=1, max_value=24),
    p=st.integers(min_value=1, max_value=3000),
    block_p=st.sampled_from([7, 64, 256, 1024, 4096]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_weighted_agg_matches_ref(k, p, block_p, seed):
    rs = np.random.RandomState(seed)
    stack = rand(rs, k, p)
    w = jnp.asarray(rs.uniform(0.0, 2.0, size=k).astype(np.float32))
    got = weighted_agg(stack, w, block_p=block_p)
    want = weighted_agg_ref(stack, w)
    assert got.shape == (p,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(min_value=2, max_value=21),
    nzero=st.integers(min_value=1, max_value=20),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_weighted_agg_padded_rows_ignored(k, nzero, seed):
    """Rows with zero weight (MEP padding for absent neighbors) must not
    affect the aggregate."""
    rs = np.random.RandomState(seed)
    p = 513
    stack = rand(rs, k, p)
    w = jnp.asarray(rs.uniform(0.1, 1.0, size=k).astype(np.float32))
    nz = min(nzero, k - 1)
    # zero out the last nz weights and replace those rows with garbage
    w = w.at[k - nz:].set(0.0)
    poisoned = stack.at[k - nz:].set(1e30)
    got = weighted_agg(poisoned, w, block_p=256)
    want = weighted_agg_ref(stack[: k - nz], w[: k - nz])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


def test_weighted_agg_single_model_identity():
    rs = np.random.RandomState(7)
    stack = rand(rs, 1, 1000)
    w = jnp.asarray([3.7], jnp.float32)
    got = weighted_agg(stack, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(stack[0]), rtol=RTOL, atol=ATOL)


def test_weighted_agg_uniform_weights_is_mean():
    rs = np.random.RandomState(8)
    stack = rand(rs, 8, 777)
    w = jnp.ones((8,), jnp.float32)
    got = weighted_agg(stack, w, block_p=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(stack.mean(0)), rtol=RTOL, atol=ATOL)


def test_weighted_agg_all_zero_weights_is_finite():
    """EPS guard: an all-zero weight vector yields zeros, not NaN."""
    rs = np.random.RandomState(9)
    stack = rand(rs, 4, 100)
    w = jnp.zeros((4,), jnp.float32)
    got = np.asarray(weighted_agg(stack, w))
    assert np.all(np.isfinite(got))
    np.testing.assert_allclose(got, np.zeros(100), atol=1e-3)


def test_weighted_agg_scale_invariance():
    """Scaling all confidences by a constant must not change the output."""
    rs = np.random.RandomState(10)
    stack = rand(rs, 6, 500)
    w = jnp.asarray(rs.uniform(0.1, 1.0, size=6).astype(np.float32))
    a = weighted_agg(stack, w, block_p=64)
    b = weighted_agg(stack, w * 100.0, block_p=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_weighted_agg_block_independence():
    """Result must not depend on the tile size."""
    rs = np.random.RandomState(11)
    stack = rand(rs, 5, 2049)
    w = jnp.asarray(rs.uniform(0.0, 1.0, size=5).astype(np.float32))
    outs = [np.asarray(weighted_agg(stack, w, block_p=b)) for b in (32, 100, 2049, 4096)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# sgd_step
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=20000),
    lr=st.floats(min_value=1e-5, max_value=1.0, allow_nan=False),
    block_p=st.sampled_from([13, 128, 1024, 8192]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_sgd_step_matches_ref(p, lr, block_p, seed):
    rs = np.random.RandomState(seed)
    params, grads = rand(rs, p), rand(rs, p)
    got = sgd_step(params, grads, lr, block_p=block_p)
    want = sgd_step_ref(params, grads, jnp.float32(lr))
    assert got.shape == (p,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


def test_sgd_step_zero_lr_identity():
    rs = np.random.RandomState(12)
    params, grads = rand(rs, 4097), rand(rs, 4097)
    got = sgd_step(params, grads, 0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(params), rtol=0, atol=0)


def test_sgd_step_zero_grad_identity():
    rs = np.random.RandomState(13)
    params = rand(rs, 1025)
    got = sgd_step(params, jnp.zeros_like(params), 0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(params), rtol=0, atol=0)


def test_sgd_step_linearity_in_lr():
    rs = np.random.RandomState(14)
    params, grads = rand(rs, 300), rand(rs, 300)
    d1 = np.asarray(params) - np.asarray(sgd_step(params, grads, 0.1, block_p=64))
    d2 = np.asarray(params) - np.asarray(sgd_step(params, grads, 0.2, block_p=64))
    np.testing.assert_allclose(2 * d1, d2, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# composition: an MEP aggregate of SGD-updated models (the real hot path)
# ---------------------------------------------------------------------------

def test_agg_of_sgd_updates_matches_ref_composition():
    rs = np.random.RandomState(15)
    k, p = 9, 1500
    base = rand(rs, k, p)
    grads = rand(rs, k, p)
    w = jnp.asarray(rs.uniform(0.1, 1.0, size=k).astype(np.float32))
    stepped = jnp.stack([sgd_step(base[i], grads[i], 0.05) for i in range(k)])
    got = weighted_agg(stepped, w, block_p=512)
    want_stepped = jnp.stack([sgd_step_ref(base[i], grads[i], jnp.float32(0.05)) for i in range(k)])
    want = weighted_agg_ref(want_stepped, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)
