"""L2 model zoo tests: shapes, training dynamics, aggregation semantics,
and the AOT lowering path (StableHLO -> HLO text) for every task."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import to_hlo_text


@pytest.fixture(scope="module", params=model.TASKS)
def task(request):
    spec = model.build_task(request.param)
    return spec, model.make_fns(spec)


def _fake_batch(spec, seed=0):
    rs = np.random.RandomState(seed)
    if spec.x_dtype == "f32":
        x = jnp.asarray(rs.standard_normal(spec.x_shape).astype(np.float32))
    else:
        x = jnp.asarray(rs.randint(0, spec.num_classes, size=spec.x_shape).astype(np.int32))
    y = jnp.asarray(rs.randint(0, spec.num_classes, size=(spec.batch,)).astype(np.int32))
    return x, y


def test_param_counts_positive_and_stable():
    for name in model.TASKS:
        a = model.build_task(name)
        b = model.build_task(name)
        assert a.param_count > 0
        assert a.param_count == b.param_count


def test_init_shapes_and_determinism(task):
    spec, fns = task
    seed = jnp.asarray([1, 2], jnp.uint32)
    (p1,) = fns["init"](seed)
    (p2,) = fns["init"](seed)
    assert p1.shape == (spec.param_count,)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    (p3,) = fns["init"](jnp.asarray([3, 4], jnp.uint32))
    assert not np.allclose(np.asarray(p1), np.asarray(p3))


def test_train_step_shapes_and_finite(task):
    spec, fns = task
    (p,) = fns["init"](jnp.asarray([0, 5], jnp.uint32))
    x, y = _fake_batch(spec)
    new, loss = fns["train"](p, x, y, jnp.float32(0.05))
    assert new.shape == (spec.param_count,)
    assert np.isfinite(float(loss))
    assert not np.array_equal(np.asarray(new), np.asarray(p))


def test_train_reduces_loss_on_fixed_batch(task):
    """A few SGD steps on one batch must reduce its loss (sanity of bwd)."""
    spec, fns = task
    (p,) = fns["init"](jnp.asarray([0, 7], jnp.uint32))
    x, y = _fake_batch(spec, seed=3)
    _, loss0 = fns["eval"](p, x, y)
    for _ in range(10):
        p, _ = fns["train"](p, x, y, jnp.float32(0.1))
    _, loss1 = fns["eval"](p, x, y)
    assert float(loss1) < float(loss0), (float(loss0), float(loss1))


def test_eval_counts_bounded(task):
    spec, fns = task
    (p,) = fns["init"](jnp.asarray([0, 9], jnp.uint32))
    x, y = _fake_batch(spec, seed=4)
    correct, loss = fns["eval"](p, x, y)
    assert 0.0 <= float(correct) <= spec.batch
    assert np.isfinite(float(loss))


def test_agg_identity_and_mean(task):
    spec, fns = task
    rs = np.random.RandomState(11)
    stack = jnp.asarray(rs.standard_normal((model.K_MAX, spec.param_count)).astype(np.float32))
    w = jnp.zeros((model.K_MAX,), jnp.float32).at[0].set(1.0)
    (out,) = fns["agg"](stack, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(stack[0]), rtol=1e-5, atol=1e-6)
    w2 = jnp.ones((model.K_MAX,), jnp.float32)
    (out2,) = fns["agg"](stack, w2)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(stack.mean(0)), rtol=1e-4, atol=1e-5)


def test_aot_lowering_produces_hlo_text(task):
    spec, fns = task
    args = model.example_args(spec)
    for kind in ("init", "train", "eval", "agg"):
        text = to_hlo_text(jax.jit(fns[kind]).lower(*args[kind]))
        assert text.startswith("HloModule"), f"{spec.name}.{kind} missing HloModule header"
        assert "ENTRY" in text
        # the ABI the rust loader expects: a root tuple
        assert "tuple(" in text or "tuple " in text, f"{spec.name}.{kind} has no tuple root"
