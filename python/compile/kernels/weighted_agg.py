"""Pallas kernel: confidence-weighted model aggregation (the MEP hot-spot).

This is the compute core of FedLay's Model Exchange Protocol (paper
§III-C2): a client aggregates the flat parameter vectors of itself and its
(at most ``2L``) overlay neighbors, weighted by per-client confidence
values::

    omega_u = sum_j c_j * omega_j / sum_j c_j

TPU adaptation (DESIGN.md §Hardware-Adaptation)
-----------------------------------------------
The parameter axis ``P`` is tiled into ``BLOCK_P``-wide VMEM-resident
blocks; each grid step streams one ``[K, BLOCK_P]`` tile of the neighbor
stack HBM→VMEM (expressed via ``BlockSpec``), reduces over ``K`` entirely
in VMEM, and writes one ``[BLOCK_P]`` output tile. The tiny ``[K]`` weight
vector rides along unblocked (scalar-prefetch-like). The kernel is
bandwidth-bound (one pass over ``K*P`` floats), so the roofline is HBM
bandwidth, not the MXU — see EXPERIMENTS.md §Perf for the estimate.

CPU note: compiled with ``interpret=True`` — the CPU PJRT plugin cannot run
Mosaic custom-calls. Numerics are identical; structure is what we validate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS

# Parameter-axis tile.
#
# Real-TPU choice: 4096 — VMEM footprint per grid step is
# (K+1) * BLOCK_P * 4 bytes ≈ 360 KiB with K_MAX = 22, leaving ample
# double-buffering headroom in a 16 MiB VMEM (see DESIGN.md §Perf).
TPU_BLOCK_P = 4096
#
# CPU-interpret choice (what the AOT artifacts ship with): interpret=True
# lowers the grid to an HLO while-loop whose body re-materializes the full
# [K, P] operand per step; 25 steps over a 9 MB stack cost ~170 ms/agg
# (§Perf iteration 6, measured). A single-block grid removes the loop:
# ~170 ms → ~8 ms. On TPU the 4096 tile remains the documented schedule.
DEFAULT_BLOCK_P = 1 << 17


def _agg_kernel(w_ref, stack_ref, out_ref):
    """One grid step: reduce a [K, BLOCK_P] tile over K with weights [K]."""
    w = w_ref[...].astype(jnp.float32)  # [K]
    tile = stack_ref[...].astype(jnp.float32)  # [K, BLOCK_P]
    denom = jnp.maximum(jnp.sum(w), EPS)
    # Broadcast-multiply + reduce runs on the VPU; K is small (~21) so the
    # tile stays 2D and vectorizes along BLOCK_P lanes.
    acc = jnp.sum(w[:, None] * tile, axis=0)
    out_ref[...] = (acc / denom).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_p",))
def weighted_agg(stack: jnp.ndarray, weights: jnp.ndarray,
                 block_p: int = DEFAULT_BLOCK_P) -> jnp.ndarray:
    """Aggregate ``[K, P]`` models with ``[K]`` confidences → ``[P]``.

    Pads ``P`` up to a multiple of ``block_p`` so the grid is rectangular,
    then slices the pad off. Padding is free of numeric effect: padded
    columns never feed real outputs.
    """
    k, p = stack.shape
    bp = min(block_p, max(p, 1))
    p_pad = (-p) % bp
    if p_pad:
        stack = jnp.pad(stack, ((0, 0), (0, p_pad)))
    grid = (stack.shape[1] // bp,)
    out = pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[
            # weights: replicated to every grid step (block == full vector)
            pl.BlockSpec((k,), lambda i: (0,)),
            # stack: stream one [K, bp] tile per step along the P axis
            pl.BlockSpec((k, bp), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((stack.shape[1],), stack.dtype),
        interpret=True,
    )(weights, stack)
    return out[:p]
