"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact (up to float re-association)
reference implementation here. pytest (``python/tests/test_kernel.py``)
sweeps shapes/dtypes with hypothesis and asserts ``assert_allclose`` between
the kernel and the oracle, so the oracle *is* the correctness contract.
"""
from __future__ import annotations

import jax.numpy as jnp

# Guard against an all-zero weight vector (e.g. a node with no neighbors and
# a zeroed self weight). Matches the kernel's epsilon exactly.
EPS = 1e-12


def weighted_agg_ref(stack: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Confidence-weighted model aggregation (MEP, paper §III-C2).

    omega_u = sum_j c_j * omega_j / sum_j c_j

    Args:
      stack:   ``[K, P]`` — K flat model parameter vectors (self + neighbors,
               padded rows carry ``weights == 0``).
      weights: ``[K]`` — confidence values ``c_j >= 0``.

    Returns:
      ``[P]`` aggregated flat parameter vector, same dtype as ``stack``.
    """
    w = weights.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(w), EPS)
    num = jnp.einsum("k,kp->p", w, stack.astype(jnp.float32))
    return (num / denom).astype(stack.dtype)


def sgd_step_ref(params: jnp.ndarray, grads: jnp.ndarray, lr: jnp.ndarray) -> jnp.ndarray:
    """Fused SGD parameter update: ``params - lr * grads``.

    Args:
      params: ``[P]`` flat parameters.
      grads:  ``[P]`` flat gradient.
      lr:     scalar learning rate (0-d or ``[1]`` array).

    Returns:
      ``[P]`` updated parameters, dtype of ``params``.
    """
    lr32 = jnp.asarray(lr, jnp.float32).reshape(())
    out = params.astype(jnp.float32) - lr32 * grads.astype(jnp.float32)
    return out.astype(params.dtype)
