"""Pallas kernel: fused SGD parameter update ``params - lr * grads``.

Used inside every L2 train step so the parameter update is a single fused
pass over the flat parameter vector (one read of params, one of grads, one
write) instead of separate scale + subtract HLO ops.

Same tiling story as ``weighted_agg``: the ``P`` axis is cut into
``BLOCK_P`` VMEM tiles via ``BlockSpec``; the scalar learning rate rides
along as a ``[1]`` vector replicated to every grid step. Bandwidth-bound.
``interpret=True`` for CPU-PJRT executability.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_P = 8192


def _sgd_kernel(lr_ref, p_ref, g_ref, out_ref):
    lr = lr_ref[0].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    out_ref[...] = (p - lr * g).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_p",))
def sgd_step(params: jnp.ndarray, grads: jnp.ndarray, lr: jnp.ndarray,
             block_p: int = DEFAULT_BLOCK_P) -> jnp.ndarray:
    """Fused update of a flat ``[P]`` parameter vector."""
    p = params.shape[0]
    bp = min(block_p, max(p, 1))
    pad = (-p) % bp
    if pad:
        params = jnp.pad(params, (0, pad))
        grads = jnp.pad(grads, (0, pad))
    lr_vec = jnp.asarray(lr, jnp.float32).reshape((1,))
    grid = (params.shape[0] // bp,)
    out = pl.pallas_call(
        _sgd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((params.shape[0],), params.dtype),
        interpret=True,
    )(lr_vec, params, grads)
    return out[:p]
