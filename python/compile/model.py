"""L2: JAX model zoo for the FedLay reproduction (build-time only).

Three tasks mirroring the paper's Table II, over synthetic stand-ins
(DESIGN.md §Substitutions):

  * ``mlp``  — 784-d, 10-class image-like task (paper: MLP on MNIST)
  * ``cnn``  — 32x32x3, 10-class image task   (paper: CNN on CIFAR-10)
  * ``lstm`` — next-character prediction, vocab 32 (paper: LSTM/Shakespeare)

Every model is exposed to the Rust coordinator (L3) through a **flat f32
parameter vector** of length ``P`` so Rust never interprets parameter
pytrees. Per model we AOT-lower four functions to HLO text
(see ``aot.py``):

  init  : (seed u32[2])                  -> (params f32[P],)
  train : (params, x, y, lr)             -> (params', loss)
  eval  : (params, x, y)                 -> (correct_count, loss)
  agg   : (stack f32[K_MAX,P], w[K_MAX]) -> (params,)

``train`` applies the L1 Pallas ``sgd_step`` kernel for the fused update,
and ``agg`` is the L1 ``weighted_agg`` kernel — both lower into the same
HLO module, so the AOT artifact carries the kernels with it.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels.sgd_step import sgd_step
from .kernels.weighted_agg import weighted_agg

# Maximum aggregation fan-in: self + 2L neighbors with the default L=5,
# rounded up. Rust pads with zero-weight rows (see mep::aggregate).
K_MAX = 22
# Batch size fixed at AOT time (shapes are static in the artifact).
BATCH = 32


class TaskSpec(NamedTuple):
    """Static description of one model task (mirrors artifacts/manifest)."""
    name: str
    param_count: int
    batch: int
    x_shape: Tuple[int, ...]
    x_dtype: str          # "f32" | "i32"
    num_classes: int
    init_fn: Callable     # key -> pytree
    apply_fn: Callable    # (pytree, x) -> logits [B, C]


# ---------------------------------------------------------------------------
# MLP (MNIST-like): 784 -> 128 -> 10
# ---------------------------------------------------------------------------

MLP_IN, MLP_HIDDEN, MLP_CLASSES = 784, 128, 10


def _mlp_init(key):
    k1, k2 = jax.random.split(key)
    s1 = jnp.sqrt(2.0 / MLP_IN)
    s2 = jnp.sqrt(2.0 / MLP_HIDDEN)
    return {
        "w1": jax.random.normal(k1, (MLP_IN, MLP_HIDDEN), jnp.float32) * s1,
        "b1": jnp.zeros((MLP_HIDDEN,), jnp.float32),
        "w2": jax.random.normal(k2, (MLP_HIDDEN, MLP_CLASSES), jnp.float32) * s2,
        "b2": jnp.zeros((MLP_CLASSES,), jnp.float32),
    }


def _mlp_apply(p, x):
    h = jnp.maximum(x @ p["w1"] + p["b1"], 0.0)
    return h @ p["w2"] + p["b2"]


# ---------------------------------------------------------------------------
# CNN (CIFAR-like): conv3->8, pool, conv8->16, pool, dense -> 10
# ---------------------------------------------------------------------------

CNN_HW, CNN_CH, CNN_CLASSES = 16, 3, 10  # 16x16x3 synthetic "CIFAR"
_C1, _C2 = 8, 16
_FLAT = (CNN_HW // 4) * (CNN_HW // 4) * _C2


def _cnn_init(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "k1": jax.random.normal(k1, (3, 3, CNN_CH, _C1), jnp.float32) * jnp.sqrt(2.0 / (9 * CNN_CH)),
        "b1": jnp.zeros((_C1,), jnp.float32),
        "k2": jax.random.normal(k2, (3, 3, _C1, _C2), jnp.float32) * jnp.sqrt(2.0 / (9 * _C1)),
        "b2": jnp.zeros((_C2,), jnp.float32),
        "w": jax.random.normal(k3, (_FLAT, CNN_CLASSES), jnp.float32) * jnp.sqrt(2.0 / _FLAT),
        "b": jnp.zeros((CNN_CLASSES,), jnp.float32),
    }


def _conv(x, k):
    return jax.lax.conv_general_dilated(
        x, k, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _pool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def _cnn_apply(p, x):
    # x arrives flat [B, HW*HW*CH]; static reshape inside the artifact.
    b = x.shape[0]
    img = x.reshape(b, CNN_HW, CNN_HW, CNN_CH)
    h = jnp.maximum(_conv(img, p["k1"]) + p["b1"], 0.0)
    h = _pool2(h)
    h = jnp.maximum(_conv(h, p["k2"]) + p["b2"], 0.0)
    h = _pool2(h)
    h = h.reshape(b, -1)
    return h @ p["w"] + p["b"]


# ---------------------------------------------------------------------------
# LSTM (Shakespeare-like): vocab 32, embed 16, hidden 64, seq 32
# ---------------------------------------------------------------------------

LSTM_VOCAB, LSTM_EMBED, LSTM_HIDDEN, LSTM_SEQ = 32, 16, 64, 32


def _lstm_init(key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = jnp.sqrt(1.0 / (LSTM_EMBED + LSTM_HIDDEN))
    return {
        "embed": jax.random.normal(k1, (LSTM_VOCAB, LSTM_EMBED), jnp.float32) * 0.1,
        "wx": jax.random.normal(k2, (LSTM_EMBED, 4 * LSTM_HIDDEN), jnp.float32) * s_in,
        "wh": jax.random.normal(k3, (LSTM_HIDDEN, 4 * LSTM_HIDDEN), jnp.float32) * s_in,
        "b": jnp.zeros((4 * LSTM_HIDDEN,), jnp.float32),
        "wo": jax.random.normal(k4, (LSTM_HIDDEN, LSTM_VOCAB), jnp.float32) * jnp.sqrt(1.0 / LSTM_HIDDEN),
        "bo": jnp.zeros((LSTM_VOCAB,), jnp.float32),
    }


def _lstm_apply(p, x):
    """x: int32 [B, T] char ids -> logits [B, VOCAB] for the next char."""
    b = x.shape[0]
    emb = p["embed"][x]  # [B, T, E]

    def cell(carry, xt):
        h, c = carry
        z = xt @ p["wx"] + h @ p["wh"] + p["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    h0 = jnp.zeros((b, LSTM_HIDDEN), jnp.float32)
    # scan over time keeps the artifact compact (no 32x unroll).
    (h, _), _ = jax.lax.scan(cell, (h0, h0), jnp.swapaxes(emb, 0, 1))
    return h @ p["wo"] + p["bo"]


# ---------------------------------------------------------------------------
# Flat-parameter plumbing shared by all tasks
# ---------------------------------------------------------------------------

def _flat_machinery(init_fn):
    template = init_fn(jax.random.PRNGKey(0))
    flat0, unravel = ravel_pytree(template)
    return int(flat0.shape[0]), unravel


def _cross_entropy(logits, y):
    logz = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logz, y[:, None], axis=1))


def build_task(name: str) -> TaskSpec:
    if name == "mlp":
        init_fn, apply_fn = _mlp_init, _mlp_apply
        x_shape, x_dtype, classes = (BATCH, MLP_IN), "f32", MLP_CLASSES
    elif name == "cnn":
        init_fn, apply_fn = _cnn_init, _cnn_apply
        x_shape, x_dtype, classes = (BATCH, CNN_HW * CNN_HW * CNN_CH), "f32", CNN_CLASSES
    elif name == "lstm":
        init_fn, apply_fn = _lstm_init, _lstm_apply
        x_shape, x_dtype, classes = (BATCH, LSTM_SEQ), "i32", LSTM_VOCAB
    else:
        raise ValueError(f"unknown task {name!r}")
    p, _ = _flat_machinery(init_fn)
    return TaskSpec(name, p, BATCH, x_shape, x_dtype, classes, init_fn, apply_fn)


def make_fns(spec: TaskSpec) -> Dict[str, Callable]:
    """Build the four AOT-able functions for one task.

    All return tuples (jax.jit lowering with ``return_tuple=True`` on the
    XlaComputation side gives the Rust loader a uniform 1..2-tuple ABI).
    """
    _, unravel = _flat_machinery(spec.init_fn)

    def init(seed):
        key = jax.random.wrap_key_data(seed.astype(jnp.uint32), impl="threefry2x32")
        flat, _ = ravel_pytree(spec.init_fn(key))
        return (flat,)

    def loss_fn(flat, x, y):
        logits = spec.apply_fn(unravel(flat), x)
        return _cross_entropy(logits, y)

    def train(flat, x, y, lr):
        loss, g = jax.value_and_grad(loss_fn)(flat, x, y)
        # L1 Pallas kernel: fused scale-subtract over the flat vector.
        new = sgd_step(flat, g, lr)
        return (new, loss)

    def evaluate(flat, x, y):
        logits = spec.apply_fn(unravel(flat), x)
        correct = jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
        return (correct, _cross_entropy(logits, y))

    def agg(stack, weights):
        # L1 Pallas kernel: confidence-weighted aggregation (MEP §III-C2).
        return (weighted_agg(stack, weights),)

    return {"init": init, "train": train, "eval": evaluate, "agg": agg}


def example_args(spec: TaskSpec):
    """ShapeDtypeStructs used to lower each function of a task."""
    f32, i32, u32 = jnp.float32, jnp.int32, jnp.uint32
    xd = f32 if spec.x_dtype == "f32" else i32
    P = spec.param_count
    return {
        "init": (jax.ShapeDtypeStruct((2,), u32),),
        "train": (
            jax.ShapeDtypeStruct((P,), f32),
            jax.ShapeDtypeStruct(spec.x_shape, xd),
            jax.ShapeDtypeStruct((spec.batch,), i32),
            jax.ShapeDtypeStruct((), f32),
        ),
        "eval": (
            jax.ShapeDtypeStruct((P,), f32),
            jax.ShapeDtypeStruct(spec.x_shape, xd),
            jax.ShapeDtypeStruct((spec.batch,), i32),
        ),
        "agg": (
            jax.ShapeDtypeStruct((K_MAX, P), f32),
            jax.ShapeDtypeStruct((K_MAX,), f32),
        ),
    }


TASKS = ("mlp", "cnn", "lstm")
