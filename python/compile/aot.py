"""AOT compiler: lower the L2 model zoo to HLO-text artifacts for Rust.

Run once at build time (``make artifacts``); Python never appears on the
request path. For every task in ``model.TASKS`` this emits::

    artifacts/<task>_init.hlo.txt
    artifacts/<task>_train.hlo.txt
    artifacts/<task>_eval.hlo.txt
    artifacts/<task>_agg.hlo.txt

plus ``artifacts/manifest.txt`` — a key=value description of every artifact
(shapes, dtypes, param counts) parsed by ``rust/src/runtime/artifacts.rs``.

Interchange format is HLO **text**, not serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""
from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple ABI)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_task(spec: model.TaskSpec, outdir: str, manifest: list) -> None:
    fns = model.make_fns(spec)
    args = model.example_args(spec)
    for kind in ("init", "train", "eval", "agg"):
        lowered = jax.jit(fns[kind]).lower(*args[kind])
        text = to_hlo_text(lowered)
        fname = f"{spec.name}_{kind}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        manifest.append(f"artifact.{spec.name}.{kind} = {fname}")
        print(f"  {fname}: {len(text)} chars")
    manifest.extend([
        f"task.{spec.name}.param_count = {spec.param_count}",
        f"task.{spec.name}.batch = {spec.batch}",
        f"task.{spec.name}.x_len = {spec.x_shape[1]}",
        f"task.{spec.name}.x_dtype = {spec.x_dtype}",
        f"task.{spec.name}.classes = {spec.num_classes}",
    ])


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower the model zoo to HLO text")
    ap.add_argument("--out", default="../artifacts/manifest.txt",
                    help="manifest path; artifacts land in its directory")
    ap.add_argument("--tasks", default=",".join(model.TASKS))
    args = ap.parse_args()

    outdir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(outdir, exist_ok=True)
    manifest = [f"k_max = {model.K_MAX}"]
    tasks = [t for t in args.tasks.split(",") if t]
    manifest.append(f"tasks = {','.join(tasks)}")
    for name in tasks:
        spec = model.build_task(name)
        print(f"lowering task {name} (P={spec.param_count})")
        lower_task(spec, outdir, manifest)
    with open(args.out, "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest with {len(manifest)} entries to {args.out}")


if __name__ == "__main__":
    main()
